"""Benchmark driver: one function per paper table. Prints
``name,us_per_call,derived`` CSV rows.

  python -m benchmarks.run [--quick] [--only table1,fig2,...]
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer rounds (CI-speed)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. table1,fig2")
    args = ap.parse_args()

    from benchmarks import (fig2_similarity, nlg_generation, roofline,
                            serving_chaos, serving_decode_fused,
                            serving_prefix, serving_refresh, serving_sgmv,
                            serving_sharded, serving_throughput,
                            serving_tiering, table1_accuracy, table2_comm,
                            table3_heterogeneity, table4_clients,
                            table5_rank, table10_compression)

    q = args.quick
    suites = {
        "table1": lambda: table1_accuracy.main(rounds=20 if q else 60),
        "table2": lambda: table2_comm.main(rounds=30 if q else 80),
        "table3": lambda: table3_heterogeneity.main(rounds=20 if q else 60),
        "table4": lambda: table4_clients.main(rounds=10 if q else 40),
        "table5": lambda: table5_rank.main(rounds=15 if q else 50),
        "fig2": lambda: fig2_similarity.main(rounds=10 if q else 25),
        "nlg": lambda: nlg_generation.main(rounds=10 if q else 30),
        "table10": lambda: table10_compression.main(rounds=20 if q else 50),
        "roofline": roofline.main,
        "serving": lambda: serving_throughput.main(
            new_tokens=12 if q else 24),
        "refresh": lambda: serving_refresh.main(
            requests=6 if q else 12, rounds=1 if q else 2),
        "sgmv": lambda: serving_sgmv.main(new_tokens=12 if q else 24),
        "decode": lambda: serving_decode_fused.main(
            new_tokens=12 if q else 24,
            ticks=(1, 8) if q else (1, 4, 8, 16)),
        "chaos": lambda: serving_chaos.main(
            requests=12 if q else 18, new_tokens=6 if q else 8),
        "tiering": lambda: serving_tiering.main(
            accesses=800 if q else 2000),
        "prefix": lambda: serving_prefix.main(
            requests=12 if q else 24,
            prefix_tokens=224 if q else 448,
            max_seq=256 if q else 512,
            n_pages=44 if q else 72),
        # needs XLA_FLAGS=--xla_force_host_platform_device_count=N set
        # before any jax import (the module sets it only when unset, and
        # the sibling imports above may initialize jax first)
        "sharded": lambda: serving_sharded.main(
            requests=8 if q else 16, new_tokens=8 if q else 16),
    }
    only = set(args.only.split(",")) if args.only else set(suites)
    for name, fn in suites.items():
        if name not in only:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        fn()
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == '__main__':
    main()
