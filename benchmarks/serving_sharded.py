"""Mesh-sharded serving vs the single-device engine — 1-vs-N arms on
forced host devices, token parity asserted in-bench.

The PR-9 claim is about *correctness under partitioning*, not CPU
speed: the engine sharded over an (N, 1) ("data", "model") mesh — base
weights placed, KV page pool and decode rows split N ways, adapter
slot tables replicated — must emit BIT-IDENTICAL tokens to the
single-device engine on the same workload, while the versioned refresh
flip commits through the mesh-wide collective check. On real
accelerators row sharding buys decode throughput; on CPU the forced
host devices (``--xla_force_host_platform_device_count``) share the
same cores, so the collectives and partitioned dispatch are pure
overhead — the gated ``sharded_decode_ratio`` (sharded ÷ single
decode tok/s) therefore has a deliberately low floor and exists to
catch *collapses* (a retrace storm, a host-sync explosion, an
all-gather on the hot path), not to demand speedup.

Arms, same model / prompts / greedy decode, paged layout, fused decode:

  single       shard_serving=False — the PR-8 engine
  sharded@N    shard_serving=True, mesh_shape=(N, 1)

A mid-stream publish lands in the sharded arm's registry before the
timed pass, so the record also witnesses ≥1 collective flip. Results →
``BENCH_sharded.json``.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python benchmarks/serving_sharded.py \\
      [--requests 16] [--new-tokens 16] [--mesh-data 4]
"""
from __future__ import annotations

import argparse
import os
import pathlib

# the forced device count must be in place BEFORE jax initializes; a
# no-op when the caller (CI, benchmarks/run.py) already exported it
if os.environ.get("XLA_FLAGS") is None:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import init_model
from repro.serving.demo import synthetic_clients

try:                       # python -m benchmarks.serving_sharded / run.py
    from benchmarks.common import emit, latency_row, write_record
    from benchmarks.serving_throughput import run_engine
except ImportError:        # python benchmarks/serving_sharded.py
    from common import emit, latency_row, write_record
    from serving_throughput import run_engine

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_sharded.json"


def _row(rep):
    keys = ("tok_per_s", "gen_tok_per_s", "decode_tok_per_s",
            "decode_tokens", "decode_steps", "decode_retraces",
            "host_syncs", "batch_occupancy", "wall_s", "sharded",
            "mesh_shape", "collective_flips", "cross_shard_allocs",
            "adapter_version", "flips")
    row = {k: rep[k] for k in keys if k in rep}
    row["latency"] = latency_row(rep)
    return row


def _tokens(eng):
    return {r: eng.finished[r]["tokens"].tolist() for r in eng.finished}


def main(clients=8, batch=8, requests=16, new_tokens=16, page_size=16,
         max_seq=128, mesh_data=4, out=None):
    n_dev = len(jax.devices())
    if n_dev < mesh_data:
        raise SystemExit(
            f"serving_sharded needs {mesh_data} devices, found {n_dev}: "
            "export XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{mesh_data} before jax imports")
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=128)
    acfg = AdapterConfig(mode="fedsa", rank=8)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg, jnp.float32)
    template = {"adapters": init_adapters(key, cfg, acfg)}
    client_trees = [t["adapters"] for t in
                    synthetic_clients(template, clients, seed=11)]
    base = template["adapters"]
    hetero = [8, 24, 12, 48, 6, 32, 16, 40]
    lens = [hetero[i % len(hetero)] for i in range(requests)]
    assert max(lens) + new_tokens <= max_seq
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in lens]

    common = (cfg, params, acfg, base, client_trees, prompts, new_tokens,
              batch, max_seq)

    def arm(**kw):
        rep = run_engine(*common, kv_layout="paged", page_size=page_size,
                         decode_backend="fused", keep_engine=True, **kw)
        return rep, rep.pop("_engine")

    single_rep, single_eng = arm()
    want = _tokens(single_eng)
    sharded_rep, sharded_eng = arm(shard_serving=True,
                                   mesh_shape=(mesh_data, 1))
    got = _tokens(sharded_eng)
    # the whole point: partitioning must not change a single token
    assert got == want, (
        f"sharded ({mesh_data},1) engine broke token parity with the "
        "single-device engine")

    # witness a collective flip: re-drive the sharded engine with a
    # publish landing mid-stream (versioned registry), parity again
    from repro.serving import AdapterRegistry, ServingConfig, ServingEngine
    flips = {}
    for shard in (False, True):
        reg = AdapterRegistry({"adapters": base}, n_slots=batch,
                              versioned=True)
        for i, tr in enumerate(client_trees):
            reg.ingest(i, {"adapters": tr})
        eng = ServingEngine(cfg, params, acfg, reg, ServingConfig(
            max_batch=batch, max_seq=max_seq, kv_layout="paged",
            page_size=page_size, decode_backend="fused",
            shard_serving=shard,
            mesh_shape=(mesh_data, 1) if shard else None))
        for i, p in enumerate(prompts):
            eng.submit(i % clients, p, max_new_tokens=new_tokens)
        eng.step()
        reg.publish(1, {0: {"adapters": client_trees[1]}})
        eng.run()
        flips[shard] = (_tokens(eng), eng.collective_flips, reg.flips)
    assert flips[True][0] == flips[False][0], \
        "mid-publish flip broke sharded token parity"
    collective_flips, committed_flips = flips[True][1], flips[True][2]
    assert committed_flips >= 1, "publish never committed a flip"
    assert collective_flips == committed_flips, (
        f"{committed_flips} flips committed but only {collective_flips} "
        "passed the mesh-wide collective check")

    ratio = (sharded_rep["decode_tok_per_s"]
             / single_rep["decode_tok_per_s"])
    emit("serving.single_decode_tok_per_s",
         1e6 / single_rep["decode_tok_per_s"],
         f"{single_rep['decode_tok_per_s']:.1f}")
    emit(f"serving.sharded{mesh_data}x1_decode_tok_per_s",
         1e6 / sharded_rep["decode_tok_per_s"],
         f"{sharded_rep['decode_tok_per_s']:.1f}")
    emit("serving.sharded_decode_ratio", 0.0, f"{ratio:.3f}x")
    emit("serving.sharded_cross_shard_allocs", 0.0,
         str(sharded_rep["cross_shard_allocs"]))

    bench_path = BENCH_PATH if out is None else pathlib.Path(out)
    record = {
        "bench": "serving_sharded",
        "config": {"arch": cfg.name, "n_layers": cfg.n_layers,
                   "d_model": cfg.d_model, "rank": acfg.rank,
                   "clients": clients, "batch": batch,
                   "requests": requests, "prompt_lens": lens,
                   "new_tokens": new_tokens, "max_seq": max_seq,
                   "page_size": page_size, "mesh_data": mesh_data,
                   "devices": n_dev,
                   "backend": jax.default_backend()},
        "single": _row(single_rep),
        "sharded": _row(sharded_rep),
        "token_parity": True,            # asserted above, both workloads
        "collective_flips": collective_flips,
        "sharded_decode_ratio": ratio,
    }
    write_record(bench_path, record)
    print(f"sharded ({mesh_data},1) {sharded_rep['decode_tok_per_s']:.1f} "
          f"decode tok/s vs single {single_rep['decode_tok_per_s']:.1f} → "
          f"{ratio:.3f}x, token parity OK, {collective_flips} collective "
          f"flips [{bench_path.name}]")
    return record


def _cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--mesh-data", type=int, default=4,
                    help="data-axis extent of the sharded arm's (N, 1) "
                         "mesh")
    ap.add_argument("--out", default=None,
                    help="write the JSON record here instead of the "
                         "committed BENCH_sharded.json (CI keeps the "
                         "baseline intact for the regression gate)")
    a = ap.parse_args()
    main(clients=a.clients, batch=a.batch, requests=a.requests,
         new_tokens=a.new_tokens, page_size=a.page_size,
         max_seq=a.max_seq, mesh_data=a.mesh_data, out=a.out)


if __name__ == "__main__":
    _cli()
