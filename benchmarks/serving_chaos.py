"""Chaos benchmark: serving availability under the default fault profile.

Two arms on the SAME model, request schedule and publish rounds:

  clean    live train→serve loop, no injector — the control
  faulted  ``repro.failures.default_plan(fault_seed)`` drives client
           dropout and NaN-corrupted B updates into each published
           round, drops/stalls publishes on the way to the feed, and a
           ``PagePressure`` window holds half the KV pool hostage
           mid-run; the request stream additionally carries one
           never-ingested tenant (degraded base-model serving) and a
           burst past the admission bound (deterministic shedding)

Both arms must satisfy the robustness contract — ZERO hard request
failures: every submitted request either retires with tokens or is
*explicitly* shed (``request_shed``), never lost, hung, or crashed.
``run_arm`` raises if the accounting identity breaks.

The gated metric is availability, not raw speed:

  faulted_decode_ratio = faulted decode tok/s / clean decode tok/s

floored at 0.8 by ``bench_gate.py`` (ISSUE 7 acceptance: the engine
under chaos keeps >=0.8x the clean run's decode throughput). Writes
``BENCH_chaos.json``; ``--trace-out`` saves the faulted arm's event
timeline for the CI chaos-smoke validation
(``python -m repro.obs.export --check-trace --require-events ...``).

  PYTHONPATH=src python benchmarks/serving_chaos.py \
      [--requests 18] [--fault-seed 6] [--out BENCH.json] \
      [--trace-out chaos_trace.jsonl]
"""
from __future__ import annotations

import argparse
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.core.strategies import LOCAL, leaf_role
from repro.failures import FaultInjector, PagePressure, default_plan
from repro.models.transformer import init_model
from repro.obs import TraceLog
from repro.serving import (AdapterFeed, AdapterRegistry, ServingConfig,
                           ServingEngine)
from repro.serving.demo import synthetic_clients

try:
    from benchmarks.common import emit, write_record
except ImportError:  # pragma: no cover - direct script invocation
    from common import emit, write_record

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_chaos.json"

# the faulted arm's PagePressure window, in engine steps after the
# midpoint submit — step-indexed (not wall-clock) so the fault timeline
# is reproducible across hosts
PRESSURE_STEPS = 12


def make_rounds(template, clients, rounds, seed=5):
    """Per-round client populations (round r: fresh B_i per client)."""
    return [synthetic_clients(template, clients, seed=seed + r)
            for r in range(rounds + 1)]


def _corrupt_locals(stacked, mask, mode):
    """NaN the LOCAL (B_i) leaves of masked clients in a client-axis
    tree — the divergent-update failure mode arriving at the bridge."""
    m = np.asarray(mask)

    def f(path, leaf):
        if leaf_role(path, mode) != LOCAL:
            return leaf
        bad = jnp.asarray(m.reshape((-1,) + (1,) * (leaf.ndim - 1)))
        return jnp.where(bad, jnp.nan, leaf)

    return jax.tree_util.tree_map_with_path(f, stacked)


def run_arm(cfg, params, acfg, rounds_trees, prompts, *, batch, max_seq,
            page_size, new_tokens, max_queue, burst, injector=None,
            trace=None):
    """One serving run over ``prompts`` with publishes between segments.

    Returns ``(report, chaos)`` where ``chaos`` collects the robustness
    counters. Raises on any hard failure: a request neither retired nor
    explicitly shed, or a retired request with no tokens."""
    clients = len(rounds_trees[0])
    rounds = len(rounds_trees) - 1
    reg = AdapterRegistry(rounds_trees[0][0], n_slots=batch,
                          versioned=True, validate_publish=True,
                          flip_patience=64)
    for i, t in enumerate(rounds_trees[0]):
        reg.ingest(i, t)
    feed = AdapterFeed()
    engine = ServingEngine(cfg, params, acfg, reg,
                           ServingConfig(max_batch=batch, max_seq=max_seq,
                                         page_size=page_size,
                                         max_queue=max_queue,
                                         degrade_after_s=2.0),
                           feed=feed, trace=trace)
    # warm-up compiles prefill/decode variants (untimed, both arms)
    engine.submit(0, prompts[0], max_new_tokens=new_tokens)
    engine.run()
    engine.reset_stats()
    rid0 = engine.scheduler._next_rid
    shed0 = engine.scheduler.shed

    stalled = []

    def publish_round(version):
        trees = rounds_trees[version]
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *trees)
        kept = list(range(clients))
        if injector is not None:
            # dropped clients never deliver this round's B_i (they keep
            # serving their previous adapters); corrupted clients DO
            # deliver — registry publish validation must reject them
            kept = [c for c in kept
                    if not injector.client_fate(version, c)[0]]
            bad = injector.corrupt_mask(version, clients)
            if bad.any():
                stacked = _corrupt_locals(stacked, bad, acfg.mode)
            while stalled:             # a stalled round rides the next
                v0, s0, k0 = stalled.pop(0)
                feed.publish(v0, s0, clients=k0)
            if injector.drops_publish(version):
                return
            if injector.stalls_publish(version):
                stalled.append((version, stacked, kept))
                return
        feed.publish(version, stacked, clients=kept)

    total = len(prompts)
    pressure = (PagePressure(engine.pool, injector.plan.page_pressure)
                if injector is not None else None)
    press_release = None
    published = set()
    submitted = steps = 0
    burst_done = False
    t0 = time.perf_counter()
    while (submitted < total or not burst_done
           or not engine.scheduler.idle or feed.pending
           or reg.stats["pending_version"] is not None):
        for v in range(1, rounds + 1):
            if v not in published and submitted >= v * total // (rounds + 1):
                publish_round(v)
                published.add(v)
        if pressure is not None and press_release is None \
                and submitted >= total // 2:
            pressure.apply(injector)   # chaos window opens mid-stream
            press_release = steps + PRESSURE_STEPS
        if press_release is not None and steps >= press_release \
                and pressure.held:
            pressure.release()         # window closes; engine recovers
        if submitted < total:
            # one submit per step: the clean arm's queue never builds
            engine.submit(submitted % clients, prompts[submitted],
                          max_new_tokens=new_tokens)
            submitted += 1
        elif not burst_done:
            # load spike past the admission bound in ONE tick — at
            # least burst - max_queue requests shed deterministically —
            # plus one never-ingested tenant exercising degraded serve
            for j in range(burst):
                engine.submit(clients + 3 if j == 0 else j % clients,
                              prompts[j % total],
                              max_new_tokens=new_tokens)
            burst_done = True
        engine.step()
        steps += 1
        if steps > 50_000:
            raise RuntimeError("chaos arm failed to drain")
    wall = time.perf_counter() - t0
    if pressure is not None:
        pressure.release()

    rep = engine.report()
    rep["schedule_wall_s"] = wall
    sub = engine.scheduler._next_rid - rid0
    shed = engine.scheduler.shed - shed0
    done = len(engine.finished)
    if sub != done + shed:             # the zero-hard-failures contract
        raise RuntimeError(
            f"request accounting broken: {sub} submitted != "
            f"{done} finished + {shed} shed")
    empty = [r for r, rec in engine.finished.items()
             if len(rec["tokens"]) == 0]
    if empty:
        raise RuntimeError(f"requests retired without tokens: {empty}")
    chaos = {
        "submitted": sub, "finished": done, "shed": shed,
        "degraded_served": rep["degraded_served"],
        "deadline_retired": rep["deadline_retired"],
        "flips": rep["flips"],
        "publish_rejects": reg.stats["publish_rejects"],
        "flip_timeouts": reg.stats["flip_timeouts"],
    }
    if injector is not None:
        chaos["faults"] = {k: injector.count(k) for k in
                           ("dropout", "corrupt", "feed_drop",
                            "feed_stall", "pressure")}
    return rep, chaos


def main(clients=6, batch=4, requests=18, rounds=3, new_tokens=8,
         max_seq=64, page_size=16, max_queue=6, fault_seed=6, out=None,
         trace_out=None):
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=128)
    acfg = AdapterConfig(mode="fedsa", rank=8)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg, jnp.float32)
    template = {"adapters": init_adapters(key, cfg, acfg)}
    rounds_trees = make_rounds(template, clients, rounds)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(6, 25)))
               for _ in range(requests)]
    burst = max_queue + 2

    kw = dict(batch=batch, max_seq=max_seq, page_size=page_size,
              new_tokens=new_tokens, max_queue=max_queue, burst=burst)
    clean_rep, clean = run_arm(cfg, params, acfg, rounds_trees, prompts,
                               **kw)
    trace = TraceLog()
    injector = FaultInjector(default_plan(fault_seed), trace=trace)
    fault_rep, faulted = run_arm(cfg, params, acfg, rounds_trees, prompts,
                                 injector=injector, trace=trace, **kw)

    ratio = (fault_rep["decode_tok_per_s"] / clean_rep["decode_tok_per_s"]
             if clean_rep["decode_tok_per_s"] else None)
    emit("serving.chaos_clean_decode_tok_per_s",
         1e6 / max(clean_rep["decode_tok_per_s"], 1e-9),
         f"{clean_rep['decode_tok_per_s']:.1f}")
    emit("serving.chaos_faulted_decode_tok_per_s",
         1e6 / max(fault_rep["decode_tok_per_s"], 1e-9),
         f"{fault_rep['decode_tok_per_s']:.1f}")
    emit("serving.chaos_faulted_decode_ratio", 0.0,
         f"{ratio:.2f}x" if ratio else "n/a")
    emit("serving.chaos_faulted_shed", 0.0, str(faulted["shed"]))
    emit("serving.chaos_faulted_degraded", 0.0,
         str(faulted["degraded_served"]))
    emit("serving.chaos_publish_rejects", 0.0,
         str(faulted["publish_rejects"]))

    record = {
        "bench": "serving_chaos",
        "config": {"arch": cfg.name, "n_layers": cfg.n_layers,
                   "d_model": cfg.d_model, "rank": acfg.rank,
                   "clients": clients, "batch": batch,
                   "requests": requests, "rounds": rounds,
                   "new_tokens": new_tokens, "max_seq": max_seq,
                   "page_size": page_size, "max_queue": max_queue,
                   "burst": burst, "fault_seed": fault_seed,
                   "backend": jax.default_backend()},
        "clean": {"decode_tok_per_s": clean_rep["decode_tok_per_s"],
                  "wall_s": clean_rep["schedule_wall_s"], **clean},
        "faulted": {"decode_tok_per_s": fault_rep["decode_tok_per_s"],
                    "wall_s": fault_rep["schedule_wall_s"], **faulted},
        "faulted_decode_ratio": ratio,
    }
    bench_path = BENCH_PATH if out is None else pathlib.Path(out)
    write_record(bench_path, record)
    if trace_out is not None:
        trace.save(trace_out)
        print(f"chaos trace ({len(trace.events)} events) → {trace_out}")
    f = faulted
    print(f"chaos: faulted {fault_rep['decode_tok_per_s']:.1f} decode "
          f"tok/s vs clean {clean_rep['decode_tok_per_s']:.1f} → "
          f"{ratio:.2f}x with {f['faults']['dropout']} dropouts, "
          f"{f['faults']['corrupt']} corrupted updates "
          f"({f['publish_rejects']} publishes rejected), "
          f"{f['faults']['feed_drop']} feed drops, "
          f"{f['faults']['feed_stall']} stalls, {f['shed']} shed, "
          f"{f['degraded_served']} degraded — 0 hard failures "
          f"[{bench_path.name}]")
    return record


def _cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=18)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-queue", type=int, default=6)
    ap.add_argument("--fault-seed", type=int, default=6)
    ap.add_argument("--out", default=None,
                    help="write the JSON record here instead of "
                         "BENCH_chaos.json")
    ap.add_argument("--trace-out", default=None,
                    help="save the faulted arm's JSONL event timeline")
    args = ap.parse_args()
    main(clients=args.clients, batch=args.batch, requests=args.requests,
         rounds=args.rounds, new_tokens=args.new_tokens,
         max_seq=args.max_seq, page_size=args.page_size,
         max_queue=args.max_queue, fault_seed=args.fault_seed,
         out=args.out, trace_out=args.trace_out)


if __name__ == "__main__":
    _cli()
