"""Generic SGMV serving: grouped personal-A decode vs the per-client
sequential loop, with the bgmv-legal FedSA workload as the reference.

The PR-4 claim: grouped multi-tenant serving no longer needs FedSA's
batch-global-Ā invariant. A fleet whose tenants own their WHOLE adapter
pair (FedIT-style plain LoRA, FedDPA personal adapters) — or a
mode-heterogeneous fleet mixing such tenants with FedSA ones — serves
in ONE grouped decode batch through the registry's per-client A tables
and the per-row-A gather (the SGMV path), instead of one sequential
batch-1 loop per client (the only pre-PR-4 option for personal-A
adapters, since the engine rejected those modes outright).

Three arms, same model / prompts / greedy decode, warmed jit caches:

  sgmv       grouped engine over 8 personal-A (fedit) clients — the
             per-row-A gather path
  perclient  sequential per-client prefill+decode over the same fleet
             (what a personal-A deployment had to do before)
  fedsa      grouped engine over a same-shape FedSA fleet — the
             bgmv-legal workload, quantifying what the per-row-A
             generality costs relative to the shared-Ā fast path

On this CPU host the timed engines run the grouped jnp gather paths
(``lora_backend="jnp"``) — the fused Pallas kernels execute in
interpret mode here and are not a hot path; ``repro.kernels.sgmv`` is
parity-checked against its jnp oracle and the error recorded, mirroring
how ``serving_throughput.py`` treats bgmv. Results →
``BENCH_sgmv.json``.

  PYTHONPATH=src python benchmarks/serving_sgmv.py \
      [--clients 8] [--requests 16] [--new-tokens 24]
"""
from __future__ import annotations

import argparse
import pathlib
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import decode_step, init_model, prefill
from repro.serving import AdapterRegistry, ServingConfig, ServingEngine
from repro.serving.demo import synthetic_clients

try:                       # python -m benchmarks.serving_sgmv / run.py
    from benchmarks.common import emit, latency_row, write_record
except ImportError:        # python benchmarks/serving_sgmv.py
    from common import emit, latency_row, write_record

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_sgmv.json"


def run_grouped(cfg, params, acfg, template, trees, reg_mode, prompts,
                new_tokens, batch, max_seq, **engine_kw):
    """Grouped engine over the given fleet: warm-up pass, then the timed
    pass on the SAME engine (jit caches live on its wrapped functions)."""
    reg = AdapterRegistry(template, n_slots=batch, mode=reg_mode)
    for i, tr in enumerate(trees):
        reg.ingest(i, tr)
    engine = ServingEngine(cfg, params, acfg, reg,
                           ServingConfig(max_batch=batch, max_seq=max_seq,
                                         **engine_kw))
    for timed in (False, True):
        engine.reset_stats()
        for i, p in enumerate(prompts):
            engine.submit(i % len(trees), p, max_new_tokens=new_tokens)
        rep = engine.run()
    return rep


def run_perclient(cfg, params, acfg, trees, prompts, new_tokens, max_seq):
    """Sequential batch-1 loop with each client's FULL adapter pair —
    the pre-SGMV serving story for personal-A tenants (warm-up pass,
    then timed pass on the same jitted functions)."""
    step = jax.jit(lambda ad, t, p, c: decode_step(cfg, params, ad, acfg,
                                                   t, p, c))
    pre = jax.jit(lambda ad, toks: prefill(cfg, params, ad, acfg, toks,
                                           max_seq,
                                           cache_dtype=jnp.float32))
    for timed in (False, True):
        tokens = 0
        t0 = time.perf_counter()
        for i, p in enumerate(prompts):
            ad = trees[i % len(trees)]["adapters"]
            toks = jnp.asarray(p[None].astype(np.int32))
            logits, cache, _ = pre(ad, toks)
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            tokens += 1
            for s in range(new_tokens - 1):
                pos = jnp.full((1,), len(p) + s, jnp.int32)
                logits, cache = step(ad, tok, pos, cache)
                tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
                tokens += 1
            jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
    return tokens, dt


def bench_kernel(cfg, acfg, batch):
    """Generic SGMV kernel (interpret mode, CPU) vs the jnp oracle —
    parity record, not a hot path on this backend."""
    from repro.kernels import ops, ref
    K = N = max(128, cfg.d_model)
    r = acfg.rank
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    M = max(8, batch)
    x = jax.random.normal(ks[0], (M, K), jnp.float32)
    w = jax.random.normal(ks[1], (K, N), jnp.float32) * 0.05
    a = jax.random.normal(ks[2], (batch, K, r), jnp.float32) * 0.05
    bs = jax.random.normal(ks[3], (batch, r, N), jnp.float32) * 0.05
    sid = jax.random.randint(ks[4], (M,), 0, batch)
    y = ops.sgmv(x, w, a, bs, sid, acfg.scaling, bm=M, bn=128, bk=128)
    y0 = ref.sgmv_ref(x, w, a, bs, sid, acfg.scaling)
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                - y0.astype(jnp.float32))))
    emit("serving.sgmv_kernel_max_err", 0.0, f"{err:.2e}")
    assert err < 1e-4, err
    return err


def _row(rep):
    keys = ("tok_per_s", "gen_tok_per_s", "decode_tok_per_s",
            "decode_steps", "batch_occupancy", "adapter_hit_rate",
            "wall_s", "kv_layout", "lora_backend", "registry_mode")
    row = {k: rep[k] for k in keys if k in rep}
    row["latency"] = latency_row(rep)
    return row


def main(clients=8, batch=8, requests=16, new_tokens=24, page_size=16,
         max_seq=128, out=None):
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=128)
    acfg = AdapterConfig(mode="fedsa", rank=8)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg, jnp.float32)
    template = {"adapters": init_adapters(key, cfg, acfg)}
    # personal-A fleet: every client owns (A_i, B_i)
    fedit_trees = synthetic_clients(template, clients, mode="fedit",
                                    seed=13)
    # same-shape FedSA fleet: shared Ā, per-client B_i (bgmv-legal)
    fedsa_trees = synthetic_clients(template, clients, mode="fedsa",
                                    seed=13)
    hetero = [8, 24, 12, 48, 6, 32, 16, 40]
    lens = [hetero[i % len(hetero)] for i in range(requests)]
    assert max(lens) + new_tokens <= max_seq
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in lens]

    sgmv = run_grouped(cfg, params, acfg, template, fedit_trees, "fedit",
                       prompts, new_tokens, batch, max_seq,
                       page_size=page_size)
    fedsa = run_grouped(cfg, params, acfg, template, fedsa_trees, "fedsa",
                        prompts, new_tokens, batch, max_seq,
                        page_size=page_size)
    pc_tokens, pc_dt = run_perclient(cfg, params, acfg, fedit_trees,
                                     prompts, new_tokens, max_seq)
    pc_tps = pc_tokens / pc_dt

    speedup = sgmv["gen_tok_per_s"] / pc_tps
    vs_fedsa = sgmv["gen_tok_per_s"] / fedsa["gen_tok_per_s"]
    emit("serving.sgmv_gen_tok_per_s", 1e6 / sgmv["gen_tok_per_s"],
         f"{sgmv['gen_tok_per_s']:.1f}")
    emit("serving.perclient_tok_per_s", pc_dt / pc_tokens * 1e6,
         f"{pc_tps:.1f}")
    emit("serving.fedsa_grouped_gen_tok_per_s",
         1e6 / fedsa["gen_tok_per_s"], f"{fedsa['gen_tok_per_s']:.1f}")
    emit("serving.sgmv_speedup_vs_perclient", 0.0, f"{speedup:.2f}x")
    emit("serving.sgmv_vs_fedsa_grouped", 0.0, f"{vs_fedsa:.2f}x")
    kerr = bench_kernel(cfg, acfg, batch)

    bench_path = BENCH_PATH if out is None else pathlib.Path(out)
    record = {
        "bench": "serving_sgmv",
        "config": {"arch": cfg.name, "n_layers": cfg.n_layers,
                   "d_model": cfg.d_model, "rank": acfg.rank,
                   "clients": clients, "batch": batch,
                   "requests": requests, "prompt_lens": lens,
                   "new_tokens": new_tokens, "max_seq": max_seq,
                   "page_size": page_size,
                   "backend": jax.default_backend()},
        "sgmv": _row(sgmv),
        "perclient": {"tok_per_s": pc_tps, "wall_s": pc_dt},
        "fedsa_grouped": _row(fedsa),
        "speedup_vs_perclient": speedup,
        "sgmv_vs_fedsa_grouped": vs_fedsa,
        "sgmv_kernel_max_err": kerr,
    }
    write_record(bench_path, record)
    print(f"sgmv grouped {sgmv['gen_tok_per_s']:.1f} gen tok/s vs "
          f"per-client loop {pc_tps:.1f} → {speedup:.2f}x at {clients} "
          f"personal-A clients ({vs_fedsa:.2f}x of the bgmv-legal FedSA "
          f"grouped path) [{bench_path.name}]")
    return record


def _cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--out", default=None,
                    help="write the JSON record here instead of the "
                         "committed BENCH_sgmv.json")
    a = ap.parse_args()
    main(clients=a.clients, batch=a.batch, requests=a.requests,
         new_tokens=a.new_tokens, page_size=a.page_size,
         max_seq=a.max_seq, out=a.out)


if __name__ == "__main__":
    _cli()
