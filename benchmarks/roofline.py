"""§Roofline: three-term analysis of every dry-run artifact.

  compute    = HLO_FLOPs_total / (chips · 197e12 bf16 FLOP/s)
  memory     = HLO_bytes_total / (chips · 819e9 B/s HBM)
  collective = collective_bytes_total / (chips · 50e9 B/s per ICI link)

``cost_analysis``/HLO parsing run on the post-SPMD per-device module, so
per-device numbers ARE total/chips — the terms below divide per-device
quantities by per-chip rates. Also reported: MODEL_FLOPS = 6·N_active·D
(train) or 2·N_active·D (inference) and its ratio to compiled FLOPs
(how much of the compiled compute is "useful").
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e-class target)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / ICI link

_PARAMS_ACTIVE = {}          # arch → active param count (cached)


def active_params(arch):
    """Non-embedding active params (MoE: top-k routed + shared only)."""
    if arch in _PARAMS_ACTIVE:
        return _PARAMS_ACTIVE[arch]
    import jax
    from repro.configs import get_config
    from repro.launch.entry import abstract_model
    cfg = get_config(arch)
    params = abstract_model(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        names = [str(p.key) for p in path if hasattr(p, "key")]
        if names[-1] == "embed":
            continue
        n = leaf.size
        if "moe" in names and "shared" not in names and names[-1] in (
                "w_gate", "w_up", "w_down"):
            n = int(n * cfg.moe.top_k / cfg.moe.n_experts)
        total += n
    _PARAMS_ACTIVE[arch] = total
    return total


def tokens_processed(shape_name, local_steps=1):
    from repro.configs import get_shape
    s = get_shape(shape_name)
    if s.kind == "train":
        return s.global_batch * s.seq_len * local_steps
    if s.kind == "prefill":
        return s.global_batch * s.seq_len
    return s.global_batch                      # decode: 1 token per row


def model_flops(arch, shape_name):
    from repro.configs import get_shape
    n = active_params(arch)
    d = tokens_processed(shape_name)
    mult = 6 if get_shape(shape_name).kind == "train" else 2
    return mult * n * d


def analyze(rec):
    """One dry-run record → roofline terms (seconds) + bottleneck.

    Prefers the trip-count-weighted HLO analysis (rec["hlo"]); XLA's own
    cost_analysis counts while bodies once and is kept only as fallback.
    """
    if rec.get("status") != "ok":
        return None
    hlo = rec.get("hlo", {})
    cost = rec.get("cost", {})
    if "flops" in hlo:
        flops_dev = hlo["flops"]
        bytes_dev = hlo["bytes"]
        coll_dev = hlo["collective_bytes"]
    else:
        flops_dev = cost.get("flops", 0.0)
        bytes_dev = cost.get("bytes accessed", 0.0)
        coll_dev = rec.get("collectives", {}).get("total_bytes", 0)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    n_dev = rec.get("n_devices", 256)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = flops_dev * n_dev
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "useful_ratio": (mf / hlo_total) if hlo_total else 0.0,
        "bound_s": max(terms.values()),
        "note": rec.get("note", ""),
    }


def load_all(dirpath="experiments/dryrun"):
    out = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        rec = json.load(open(f))
        a = analyze(rec)
        if a:
            out.append(a)
        elif rec.get("status") == "skipped":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec.get("mesh", "?"), "dominant": "SKIPPED",
                        "note": rec.get("reason", "")})
    return out


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}µs"


def markdown_table(rows, mesh="pod16x16"):
    lines = ["| arch | shape | compute | memory | collective | dominant | "
             "useful FLOP ratio |",
             "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["dominant"] == "SKIPPED":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | {r['note']} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute_s'])} | "
            f"{fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.3f} |")
    return "\n".join(lines)


def main():
    rows = load_all()
    for r in rows:
        if r["dominant"] == "SKIPPED":
            continue
        print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0,"
              f"dom={r['dominant']};bound={fmt_s(r['bound_s'])};"
              f"useful={r['useful_ratio']:.3f}", flush=True)
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline_table.md", "w") as f:
        f.write("## Single-pod (16×16)\n\n")
        f.write(markdown_table(rows, "pod16x16"))
        f.write("\n\n## Multi-pod (2×16×16)\n\n")
        f.write(markdown_table(rows, "pod2x16x16"))
        f.write("\n")
    return rows


if __name__ == "__main__":
    main()
