"""Table 5: accuracy vs LoRA rank r ∈ {2, 4, 8, 16}."""
from __future__ import annotations

from benchmarks.common import emit, make_task, run_fl


def main(rounds=50):
    out = {}
    clients, test_batch = make_task(3, 0.5, seed=17)
    for rank in [2, 4, 8, 16]:
        for mode in ["fedavg", "ffa", "fedsa"]:
            r = run_fl(mode, "lora", rank=rank, rounds=rounds,
                       clients=clients, test_batch=test_batch)
            out[(rank, mode)] = r["best_acc"]
            emit(f"table5/r{rank}/{mode}", r["s_per_round"] * 1e6,
                 f"acc={r['best_acc']:.4f}")
    return out


if __name__ == "__main__":
    main()
