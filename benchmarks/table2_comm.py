"""Table 2: system efficiency — trainable / communicated params, per-round
compute time, rounds to a target accuracy."""
from __future__ import annotations

from benchmarks.common import emit, make_task, run_fl

TARGET = 0.80


def main(rounds=80):
    out = {}
    clients, test_batch = make_task(3, 0.5, seed=7)
    for mode in ["fedavg", "ffa", "feddpa", "fedsa"]:
        r = run_fl(mode, "lora", rounds=rounds, clients=clients,
                   test_batch=test_batch, target_acc=TARGET)
        sys = r["system"]
        rtt = r["hist"]["rounds_to_target"]
        out[mode] = {
            "trainable": sys.n_trainable,
            "comm_per_round": sys.comm_per_round,
            "s_per_round": r["s_per_round"],
            "rounds_to_target": rtt,
            "total_comm_to_target": (rtt or rounds) * sys.comm_per_round,
            "acc": r["best_acc"],
        }
        emit(f"table2/{mode}", r["s_per_round"] * 1e6,
             f"trainable={sys.n_trainable};comm={sys.comm_per_round};"
             f"rounds_to_{TARGET}={rtt};acc={r['best_acc']:.4f}")
    return out


if __name__ == "__main__":
    main()
