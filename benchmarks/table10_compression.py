"""Table 10 (Appendix A.7): FedSA-LoRA with count-sketch-compressed A
updates. Clients sketch ΔA; the server averages sketches (linear), unsketches
top-k, and applies the estimate — ~50% of the A bytes on the wire.

Claim: accuracy ≈ uncompressed FedSA-LoRA at ~half the A communication.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, make_task
from repro.configs import AdapterConfig, FedConfig
from repro.core import federation
from repro.core.sketch import make_sketch, sketch, unsketch
from repro.core.strategies import SHARED, leaf_role
from repro.data.synthetic import stack_client_batch
from benchmarks.common import encoder_cfg


def _sketched_aggregate(tr_before, tr_after, mode, compression, topk):
    """Replace shared-leaf aggregation with sketch→mean→unsketch of deltas."""
    flat_b = jax.tree_util.tree_flatten_with_path(tr_before)[0]
    flat_a, treedef = jax.tree_util.tree_flatten_with_path(tr_after)
    leaves = []
    for i, ((path, before), (_, after)) in enumerate(zip(flat_b, flat_a)):
        if leaf_role(path, mode) != SHARED:
            leaves.append(after)
            continue
        C = after.shape[0]
        dim = int(np.prod(after.shape[1:]))
        state = make_sketch(i, dim, rows=5, compression=compression)
        deltas = (after - before).reshape(C, dim)
        tables = jnp.stack([sketch(state, deltas[c]) for c in range(C)])
        mean_tab = jnp.mean(tables, axis=0)
        est = unsketch(state, mean_tab, topk_frac=topk)
        new = before[0].reshape(dim) + est
        new = jnp.broadcast_to(new.reshape((1,) + after.shape[1:]),
                               after.shape).astype(after.dtype)
        leaves.append(new)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def run(compression=None, rounds=50, seed=0):
    cfg = encoder_cfg()
    clients, test_batch = make_task(3, 0.5, seed=19)
    fed = FedConfig(n_clients=3, local_steps=5)
    acfg = AdapterConfig(mode="fedsa", rank=8)
    sys = federation.build(jax.random.PRNGKey(seed), cfg, acfg, fed,
                           task="classification", n_classes=4, lr=5e-2)
    tr, ost = sys.trainables, sys.opt_state
    rng = np.random.default_rng(seed + 1)
    no_agg = jnp.zeros((3,), jnp.float32)
    full = jnp.ones((3,), jnp.float32)
    accs = []
    for r in range(rounds):
        steps = [stack_client_batch(clients, 16, rng) for _ in range(5)]
        batches = {k: jnp.asarray(np.stack([s[k] for s in steps], 1))
                   for k in steps[0]}
        if compression is None:
            tr, ost, _ = sys.round_fn(tr, ost, batches, full)
        else:
            before = tr
            tr, ost, _ = sys.round_fn(tr, ost, batches, no_agg)
            tr = _sketched_aggregate(before, tr, "fedsa", compression,
                                     topk=compression / 2)
        if (r + 1) % 10 == 0:
            accs.append(float(jnp.mean(sys.eval_fn(tr, test_batch))))
    return max(accs)


def main(rounds=50):
    out = {}
    base = run(None, rounds=rounds)
    out["fedsa"] = {"acc": base, "comm_frac": 1.0}
    emit("table10/fedsa", 0, f"acc={base:.4f};A_comm=100%")
    comp = run(0.5, rounds=rounds)
    out["fedsa_sketch"] = {"acc": comp, "comm_frac": 0.5}
    emit("table10/fedsa+sketch50", 0, f"acc={comp:.4f};A_comm=50%")
    return out


if __name__ == "__main__":
    main()
