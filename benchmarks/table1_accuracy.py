"""Table 1: strategies × variants accuracy on the GLUE-proxy (Dir(0.5)).

Paper claim under reproduction: FedSA-{LoRA, rsLoRA, VeRA} > the
corresponding {vanilla, FFA, FedDPA} baselines under non-IID data.
"""
from __future__ import annotations

from benchmarks.common import emit, make_task, run_fl

MODES = ["fedavg", "ffa", "feddpa", "fedsa"]
VARIANTS = ["lora", "rslora", "vera"]


def main(rounds=60, seeds=(0,)):
    results = {}
    for variant in VARIANTS:
        for mode in MODES:
            accs = []
            sec = 0.0
            for seed in seeds:
                clients, test_batch = make_task(3, 0.5, seed=7)
                r = run_fl(mode, variant, rounds=rounds, seed=seed,
                           clients=clients, test_batch=test_batch)
                accs.append(r["best_acc"])
                sec = r["s_per_round"]
            acc = sum(accs) / len(accs)
            results[(variant, mode)] = acc
            emit(f"table1/{variant}/{mode}", sec * 1e6, f"acc={acc:.4f}")
    return results


if __name__ == "__main__":
    main()
