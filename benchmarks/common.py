"""Shared harness for the paper-claims benchmarks.

All accuracy experiments run the host federated runtime on a reduced
RoBERTa-style encoder over the synthetic GLUE-proxy task (see
``repro.data.synthetic`` for how general vs client-specific structure is
planted). Every benchmark prints ``name,us_per_call,derived`` CSV rows and
returns a dict for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import AdapterConfig, FedConfig, get_config, reduced
from repro.core import federation
from repro.data.synthetic import make_classification_task
from repro.obs import sanitize

N_CLASSES = 4
SEQ = 24
VOCAB = 512


def encoder_cfg(n_layers=2, d_model=128):
    return reduced(get_config("roberta-large"), n_layers=n_layers,
                   d_model=d_model)


def make_task(n_clients, alpha, seed=0, n_train=1536, n_test=512,
              hetero_strength=0.35, concept_shift=None):
    clients, tests = make_classification_task(
        n_clients=n_clients, n_classes=N_CLASSES, vocab=VOCAB, seq=SEQ,
        n_train=n_train, n_test=n_test, alpha=alpha,
        hetero_strength=hetero_strength, concept_shift=concept_shift,
        seed=seed)
    test_batch = {k: jnp.asarray(np.stack([t[k][:256] for t in tests]))
                  for k in tests[0]}
    return clients, test_batch


def run_fl(mode, variant="lora", *, n_clients=3, alpha=0.5, rounds=40,
           rank=8, local_steps=5, batch_size=16, lr=None, seed=0,
           client_sample_rate=1.0, clients=None, test_batch=None,
           target_acc=None, cfg=None):
    """One federated experiment → (final_acc, history, system, per-round s)."""
    cfg = cfg or encoder_cfg()
    if clients is None:
        clients, test_batch = make_task(n_clients, alpha, seed=seed)
    fed = FedConfig(n_clients=n_clients, local_steps=local_steps,
                    client_sample_rate=client_sample_rate)
    acfg = AdapterConfig(mode=mode, variant=variant, rank=rank,
                         vera_rank=4 * rank)
    if lr is None:
        lr = 2e-3 if variant == "vera" else 5e-2
    sys = federation.build(jax.random.PRNGKey(seed), cfg, acfg, fed,
                           task="classification", n_classes=N_CLASSES,
                           lr=lr)
    t0 = time.perf_counter()
    hist = federation.run_rounds(
        sys, clients, rounds=rounds, batch_size=batch_size, seed=seed + 1,
        eval_every=max(1, rounds // 8), test_batch=test_batch,
        target_acc=target_acc)
    wall = time.perf_counter() - t0
    acc = hist["acc"][-1] if hist["acc"] else None
    return {"acc": acc, "best_acc": max(hist["acc"]) if hist["acc"]
            else None, "hist": hist, "system": sys,
            "s_per_round": wall / rounds}


def emit(name, us_per_call, derived):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


LATENCY_KEYS = tuple(f"{k}_{s}_s"
                     for k in ("queue_wait", "ttft", "intertoken", "e2e")
                     for s in ("p50", "p90", "p99", "mean"))


def latency_row(rep):
    """Latency-percentile slice of an engine report — the obs-histogram
    keys ``report()`` carries (None when the window was empty)."""
    return {k: rep.get(k) for k in LATENCY_KEYS}


def write_record(path, record):
    """Persist a BENCH record as STRICT json: every non-finite float
    (NaN/Inf, numpy or python) becomes null before serialization, and
    ``allow_nan=False`` makes any leak a hard error here rather than a
    parse failure in the regression gate."""
    path = pathlib.Path(path)
    path.write_text(json.dumps(sanitize(record), indent=2,
                               allow_nan=False) + "\n")
    return path
