"""The paper's communication claim at the HLO level.

Lowers ONLY the server aggregation op (``core.aggregation.aggregate``) for
the production mesh and measures its collective bytes per federated mode.
This is the traffic that crosses the client↔server boundary each round —
the quantity Table 2 of the paper is about. (Inside one pod the TP
activation all-reduces dwarf it; in a real cross-site FL deployment the
WAN carries only these bytes.)

  python -m benchmarks.comm_collectives [--arch deepseek-7b]
"""
from __future__ import annotations

import argparse
import os


def main():
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import AdapterConfig, get_config
    from repro.core.aggregation import aggregate
    from repro.launch.entry import abstract_adapters
    from repro.launch.hlo_analysis import analyze
    from repro.launch.mesh import make_production_mesh
    from repro.sharding.rules import adapter_specs
    from repro.launch.entry import sanitize_specs

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    args = ap.parse_args()

    mesh = make_production_mesh()
    cfg = get_config(args.arch)
    out = {}
    for mode in ["fedavg", "ffa", "fedsa", "feddpa"]:
        acfg = AdapterConfig(mode=mode)
        ad = abstract_adapters(cfg, acfg, n_clients=16)
        specs = sanitize_specs(
            ad, adapter_specs(cfg, ad, mesh, client_axis=True), mesh)
        shardings = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        fn = jax.jit(lambda a: aggregate(a, mode),  # noqa: B023
                     in_shardings=(shardings,), out_shardings=shardings)
        with mesh:
            compiled = fn.lower(ad).compile()
        res = analyze(compiled.as_text())
        out[mode] = res["collective_bytes"]
        print(f"comm_collectives/{args.arch}/{mode},0,"
              f"aggregation_coll_bytes_per_dev={res['collective_bytes']:.0f}"
              f";kinds={res['collectives']}", flush=True)
    if out.get("fedavg") and out.get("fedsa"):
        print(f"# fedsa/fedavg aggregation byte ratio: "
              f"{out['fedsa']/out['fedavg']:.3f} (paper claims 0.5)",
              flush=True)
    return out


if __name__ == "__main__":
    main()
