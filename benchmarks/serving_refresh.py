"""Live adapter refresh vs drain-and-rebuild under a weight schedule.

The PR-3 claim: because FedSA-LoRA publishes one aggregated Ā plus a
rank-r B_i per tenant each round, a running engine can absorb round t+1
through the double-buffered slot tables (``repro.serving.refresh``)
instead of draining the batch and rebuilding — which pays engine
construction plus a fresh jit of every prefill/decode variant per
round. Both arms serve the SAME requests under the SAME per-segment
weight schedule:

  live   one engine; a publish lands between segments; flips absorb it
  drain  a new engine per segment (the pre-refresh upgrade path)

Also records publish→flip latency in engine ticks and the refresh
stats (flips, staleness). Results go to ``BENCH_refresh.json``.

  PYTHONPATH=src python benchmarks/serving_refresh.py \
      [--requests 12] [--rounds 2] [--new-tokens 8]
"""
from __future__ import annotations

import argparse
import pathlib
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import init_model
from repro.serving import (AdapterFeed, AdapterRegistry, ServingConfig,
                           ServingEngine)
from repro.serving.demo import synthetic_clients

try:                       # python -m benchmarks.serving_refresh / run.py
    from benchmarks.common import emit, latency_row, write_record
except ImportError:        # python benchmarks/serving_refresh.py
    from common import emit, latency_row, write_record

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_refresh.json"


def make_rounds(template, clients, rounds, seed=5):
    """Per-round client populations (round r: fresh B_i per client —
    SHARED Ā kept so both arms share one registry template tree)."""
    return [synthetic_clients(template, clients, seed=seed + r)
            for r in range(rounds + 1)]


def segments_of(prompts, rounds):
    """Split the request list into rounds+1 contiguous segments."""
    per = -(-len(prompts) // (rounds + 1))
    return [prompts[i:i + per] for i in range(0, len(prompts), per)]


def run_live(cfg, params, acfg, rounds_trees, segs, new_tokens, batch,
             max_seq):
    clients = len(rounds_trees[0])
    reg = AdapterRegistry(rounds_trees[0][0], n_slots=batch,
                          versioned=True)
    for i, t in enumerate(rounds_trees[0]):
        reg.ingest(i, t)
    feed = AdapterFeed()
    engine = ServingEngine(cfg, params, acfg, reg,
                           ServingConfig(max_batch=batch, max_seq=max_seq),
                           feed=feed)
    # warm-up: compile prefill/decode variants on round-0 weights
    engine.submit(0, segs[0][0], max_new_tokens=new_tokens)
    engine.run()
    engine.reset_stats()
    flip_lat = []
    rid = 0
    t0 = time.perf_counter()
    for version, seg in enumerate(segs):
        if version > 0:
            feed.publish(version, jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *rounds_trees[version]))
            waited = 0
            while reg.version < version:     # publish→flip latency
                engine.step()
                waited += 1
            flip_lat.append(waited)
        for p in seg:
            engine.submit(rid % clients, p, max_new_tokens=new_tokens)
            rid += 1
        while not engine.scheduler.idle:
            engine.step()
    wall = time.perf_counter() - t0
    rep = engine.report()
    rep["schedule_wall_s"] = wall
    rep["flip_latency_ticks"] = flip_lat
    return rep


def run_drain(cfg, params, acfg, rounds_trees, segs, new_tokens, batch,
              max_seq):
    """The pre-refresh path: a publish means drain, rebuild, recompile.

    The segment-0 engine is built AND warmed before the clock starts —
    both upgrade paths pay the initial build/compile exactly once, so
    only the per-round rebuild+recompile (the refresh-vs-rebuild delta)
    is timed, mirroring the live arm's untimed warm-up."""
    clients = len(rounds_trees[0])

    def build(version):
        reg = AdapterRegistry(rounds_trees[version][0], n_slots=batch)
        for i, t in enumerate(rounds_trees[version]):
            reg.ingest(i, t)
        return ServingEngine(cfg, params, acfg, reg,
                             ServingConfig(max_batch=batch,
                                           max_seq=max_seq))

    engine = build(0)
    engine.submit(0, segs[0][0], max_new_tokens=new_tokens)
    engine.run()
    engine.reset_stats()
    tokens = 0
    rebuild_wall = 0.0
    rid = 0
    t0 = time.perf_counter()
    for version, seg in enumerate(segs):
        if version > 0:
            r0 = time.perf_counter()
            engine = build(version)
            rebuild_wall += time.perf_counter() - r0
        for p in seg:
            engine.submit(rid % clients, p, max_new_tokens=new_tokens)
            rid += 1
        engine.run()
        tokens += engine.decoded_tokens + engine.prefilled_requests
    wall = time.perf_counter() - t0
    return {"schedule_wall_s": wall, "generated_tokens": tokens,
            "rebuild_wall_s": rebuild_wall}


def main(clients=6, batch=4, requests=12, rounds=2, new_tokens=8,
         max_seq=64, out=None):
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=128)
    acfg = AdapterConfig(mode="fedsa", rank=8)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg, jnp.float32)
    template = {"adapters": init_adapters(key, cfg, acfg)}
    rounds_trees = make_rounds(template, clients, rounds)
    rng = np.random.default_rng(0)
    lens = [int(rng.integers(6, 25)) for _ in range(requests)]
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in lens]
    segs = segments_of(prompts, rounds)

    live = run_live(cfg, params, acfg, rounds_trees, segs, new_tokens,
                    batch, max_seq)
    drain = run_drain(cfg, params, acfg, rounds_trees, segs, new_tokens,
                      batch, max_seq)
    live_tps = live["generated_tokens"] / live["schedule_wall_s"]
    drain_tps = drain["generated_tokens"] / drain["schedule_wall_s"]
    speedup = live_tps / drain_tps
    emit("serving.refresh_live_tok_per_s", 1e6 / live_tps,
         f"{live_tps:.1f}")
    emit("serving.refresh_drain_tok_per_s", 1e6 / drain_tps,
         f"{drain_tps:.1f}")
    emit("serving.refresh_speedup_vs_drain", 0.0, f"{speedup:.2f}x")
    emit("serving.refresh_flip_latency_ticks", 0.0,
         "/".join(str(t) for t in live["flip_latency_ticks"]) or "0")
    emit("serving.refresh_rebuild_wall_s", drain["rebuild_wall_s"] * 1e6,
         f"{drain['rebuild_wall_s']:.2f}s")

    record = {
        "bench": "serving_refresh",
        "config": {"arch": cfg.name, "n_layers": cfg.n_layers,
                   "d_model": cfg.d_model, "rank": acfg.rank,
                   "clients": clients, "batch": batch,
                   "requests": requests, "rounds": rounds,
                   "new_tokens": new_tokens, "max_seq": max_seq,
                   "backend": jax.default_backend()},
        "live": {"tok_per_s": live_tps,
                 "wall_s": live["schedule_wall_s"],
                 "flips": live["flips"],
                 "deferred_flips": live["deferred_flips"],
                 "flip_latency_ticks": live["flip_latency_ticks"],
                 "staleness_mean": live["staleness_mean"],
                 "staleness_max": live["staleness_max"],
                 "latency": latency_row(live)},
        "drain": {"tok_per_s": drain_tps,
                  "wall_s": drain["schedule_wall_s"],
                  "rebuild_wall_s": drain["rebuild_wall_s"]},
        "speedup_vs_drain": speedup,
    }
    bench_path = BENCH_PATH if out is None else pathlib.Path(out)
    write_record(bench_path, record)
    print(f"live refresh {live_tps:.1f} gen tok/s vs drain+rebuild "
          f"{drain_tps:.1f} → {speedup:.2f}x across {rounds} adapter "
          f"rounds ({live['flips']} flips, rebuild cost "
          f"{drain['rebuild_wall_s']:.2f}s) [{bench_path.name}]")
    return record


def _cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--out", default=None,
                    help="write the JSON record here instead of the "
                         "committed BENCH_refresh.json (CI keeps the "
                         "baseline intact for the regression gate)")
    a = ap.parse_args()
    main(clients=a.clients, batch=a.batch, requests=a.requests,
         rounds=a.rounds, new_tokens=a.new_tokens, max_seq=a.max_seq,
         out=a.out)


if __name__ == "__main__":
    _cli()
