"""Table 4: accuracy vs number of clients (10 / 20 full participation,
50 with 0.3 sampling as the 100-client proxy at this scale)."""
from __future__ import annotations

from benchmarks.common import emit, make_task, run_fl


def main(rounds=40):
    out = {}
    settings = [(10, 1.0), (20, 1.0), (50, 0.3)]
    for n_clients, rate in settings:
        clients, test_batch = make_task(n_clients, 0.5, seed=13,
                                        n_train=256 * n_clients // 2)
        for mode in ["fedavg", "ffa", "fedsa"]:
            r = run_fl(mode, "lora", n_clients=n_clients, rounds=rounds,
                       client_sample_rate=rate, clients=clients,
                       test_batch=test_batch)
            out[(n_clients, mode)] = r["best_acc"]
            emit(f"table4/{n_clients}clients/{mode}",
                 r["s_per_round"] * 1e6, f"acc={r['best_acc']:.4f}")
    return out


if __name__ == "__main__":
    main()
