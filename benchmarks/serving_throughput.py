"""Multi-tenant batched decode vs. naive one-client-per-batch serving.

The FedSA-LoRA serving claim: because every client shares the aggregated
Ā and differs only in B_i, requests from DIFFERENT clients can ride one
decode batch (repro.serving). The naive baseline — what
``examples/serve_personalized.py`` did before this subsystem — decodes
each client's request alone at batch 1, so N clients cost N sequential
decode loops.

Both paths run the same model, the same per-request prefill, and the same
greedy decode on the host backend; the only difference is batching across
tenants. Also times the grouped ``bgmv`` kernel (interpret mode) against
its jnp reference at one serving-shaped operand set for the record.

  PYTHONPATH=src python benchmarks/serving_throughput.py [--clients 8]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import decode_step, init_model, prefill
from repro.serving import AdapterRegistry, ServingEngine
from repro.serving.demo import synthetic_clients

try:                       # python -m benchmarks.serving_throughput / run.py
    from benchmarks.common import emit
except ImportError:        # python benchmarks/serving_throughput.py
    from common import emit


def run_multi_tenant(cfg, params, acfg, base, client_trees, prompts,
                     new_tokens, batch, max_seq):
    """Warm-up pass (compiles), then the timed pass on the SAME engine —
    jit caches live on the engine's wrapped functions."""
    reg = AdapterRegistry({"adapters": base}, n_slots=batch)
    for i, tr in enumerate(client_trees):
        reg.ingest(i, {"adapters": tr})
    engine = ServingEngine(cfg, params, acfg, reg, max_batch=batch,
                           max_seq=max_seq)
    for timed in (False, True):
        engine.reset_stats()
        for i, p in enumerate(prompts):
            engine.submit(i % len(client_trees), p,
                          max_new_tokens=new_tokens)
        t0 = time.perf_counter()
        rep = engine.run()
        dt = time.perf_counter() - t0
    return rep["tokens"], dt, rep


def run_naive(cfg, params, acfg, client_trees, prompts, new_tokens,
              max_seq):
    """One client per batch: sequential batch-1 prefill+decode loops
    (warm-up pass, then timed pass on the same jitted functions)."""
    step = jax.jit(lambda ad, t, p, c: decode_step(cfg, params, ad, acfg,
                                                   t, p, c))
    pre = jax.jit(lambda ad, toks: prefill(cfg, params, ad, acfg, toks,
                                           max_seq,
                                           cache_dtype=jnp.float32))
    for timed in (False, True):
        tokens = 0
        t0 = time.perf_counter()
        for i, p in enumerate(prompts):
            ad = client_trees[i % len(client_trees)]
            toks = jnp.asarray(p[None].astype(np.int32))
            logits, cache, _ = pre(ad, toks)
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            tokens += 1
            for s in range(new_tokens - 1):
                pos = jnp.full((1,), len(p) + s, jnp.int32)
                logits, cache = step(ad, tok, pos, cache)
                tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
                tokens += 1
            jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
    return tokens, dt


def bench_kernel(cfg, acfg, batch):
    """Grouped kernel (interpret mode, CPU) vs jnp reference — parity
    record, not a hot path on this backend."""
    from repro.kernels import ops, ref
    K = N = max(128, cfg.d_model)
    r = acfg.rank
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    M = max(8, batch)
    x = jax.random.normal(ks[0], (M, K), jnp.float32)
    w = jax.random.normal(ks[1], (K, N), jnp.float32) * 0.05
    a = jax.random.normal(ks[2], (K, r), jnp.float32) * 0.05
    bs = jax.random.normal(ks[3], (batch, r, N), jnp.float32) * 0.05
    sid = jax.random.randint(ks[4], (M,), 0, batch)
    y = ops.bgmv(x, w, a, bs, sid, acfg.scaling, bm=M, bn=128, bk=128)
    y0 = ref.bgmv_ref(x, w, a, bs, sid, acfg.scaling)
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                - y0.astype(jnp.float32))))
    emit("serving.bgmv_kernel_max_err", 0.0, f"{err:.2e}")
    assert err < 1e-4, err


def main(clients=8, batch=8, requests=8, prompt_len=12, new_tokens=24):
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=128)
    acfg = AdapterConfig(mode="fedsa", rank=8)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg, jnp.float32)
    template = {"adapters": init_adapters(key, cfg, acfg)}
    client_trees = [t["adapters"] for t in
                    synthetic_clients(template, clients, seed=11)]
    base = template["adapters"]
    max_seq = prompt_len + new_tokens
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len)
               for _ in range(requests)]

    mt_tokens, mt_dt, rep = run_multi_tenant(
        cfg, params, acfg, base, client_trees, prompts, new_tokens,
        batch, max_seq)
    nv_tokens, nv_dt = run_naive(cfg, params, acfg, client_trees, prompts,
                                 new_tokens, max_seq)

    mt_tps = mt_tokens / mt_dt
    nv_tps = nv_tokens / nv_dt
    emit("serving.multi_tenant_tok_per_s", mt_dt / mt_tokens * 1e6,
         f"{mt_tps:.1f}")
    emit("serving.naive_sequential_tok_per_s", nv_dt / nv_tokens * 1e6,
         f"{nv_tps:.1f}")
    emit("serving.speedup", 0.0, f"{mt_tps / nv_tps:.2f}x")
    emit("serving.batch_occupancy", 0.0, f"{rep['batch_occupancy']:.2f}")
    emit("serving.adapter_hit_rate", 0.0, f"{rep['adapter_hit_rate']:.2f}")
    bench_kernel(cfg, acfg, batch)
    print(f"multi-tenant {mt_tps:.1f} tok/s vs naive {nv_tps:.1f} tok/s "
          f"→ {mt_tps / nv_tps:.2f}x at {clients} clients / "
          f"batch {batch}")


def _cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=24)
    a = ap.parse_args()
    main(clients=a.clients, batch=a.batch, requests=a.requests,
         prompt_len=a.prompt_len, new_tokens=a.new_tokens)


if __name__ == "__main__":
    _cli()
