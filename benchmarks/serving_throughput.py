"""Serving throughput: paged + chunked-batched-prefill engine vs the
PR-1 dense/batch-1-prefill engine vs naive one-client-per-batch serving.

The FedSA-LoRA serving claim (PR 1): because every client shares the
aggregated Ā and differs only in B_i, requests from DIFFERENT clients
can ride one decode batch. This benchmark adds the PR-2 claim on top: a
paged KV cache (block tables + page pool) with length-bucketed batched
prefill stops charging every sequence for ``max_seq`` — prompts are
prefilled in a handful of batched power-of-two buckets instead of one
batch-1 pass per request, and decode attends only over the page bucket
covering the deepest active row.

All engines run the same model and the same greedy decode on the host
backend with a warm-up pass (jit caches live on the engine's wrapped
functions), over a *heterogeneous* prompt-length mix. Results are
persisted to ``BENCH_serving.json`` at the repo root so the perf
trajectory is machine-readable across PRs.

  PYTHONPATH=src python benchmarks/serving_throughput.py \
      [--requests 16] [--new-tokens 24]
"""
from __future__ import annotations

import argparse
import pathlib
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import decode_step, init_model, prefill
from repro.serving import AdapterRegistry, ServingConfig, ServingEngine
from repro.serving.demo import synthetic_clients

try:                       # python -m benchmarks.serving_throughput / run.py
    from benchmarks.common import emit, latency_row, write_record
except ImportError:        # python benchmarks/serving_throughput.py
    from common import emit, latency_row, write_record

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_serving.json"


def run_engine(cfg, params, acfg, base, client_trees, prompts, new_tokens,
               batch, max_seq, keep_engine=False, **engine_kw):
    """Warm-up pass (compiles), then the timed pass on the SAME engine —
    jit caches live on the engine's wrapped functions. With
    ``keep_engine`` the report carries the engine under ``"_engine"``
    (callers that need the finished-token map, e.g. the fused-decode
    benchmark's parity check — pop it before serializing)."""
    reg = AdapterRegistry({"adapters": base}, n_slots=batch)
    for i, tr in enumerate(client_trees):
        reg.ingest(i, {"adapters": tr})
    engine = ServingEngine(cfg, params, acfg, reg,
                           ServingConfig(max_batch=batch, max_seq=max_seq,
                                         **engine_kw))
    for timed in (False, True):
        engine.reset_stats()
        for i, p in enumerate(prompts):
            engine.submit(i % len(client_trees), p,
                          max_new_tokens=new_tokens)
        rep = engine.run()
    if keep_engine:
        rep["_engine"] = engine
    return rep


def run_naive(cfg, params, acfg, client_trees, prompts, new_tokens,
              max_seq):
    """One client per batch: sequential batch-1 prefill+decode loops
    (warm-up pass, then timed pass on the same jitted functions)."""
    step = jax.jit(lambda ad, t, p, c: decode_step(cfg, params, ad, acfg,
                                                   t, p, c))
    pre = jax.jit(lambda ad, toks: prefill(cfg, params, ad, acfg, toks,
                                           max_seq,
                                           cache_dtype=jnp.float32))
    for timed in (False, True):
        tokens = 0
        t0 = time.perf_counter()
        for i, p in enumerate(prompts):
            ad = client_trees[i % len(client_trees)]
            toks = jnp.asarray(p[None].astype(np.int32))
            logits, cache, _ = pre(ad, toks)
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            tokens += 1
            for s in range(new_tokens - 1):
                pos = jnp.full((1,), len(p) + s, jnp.int32)
                logits, cache = step(ad, tok, pos, cache)
                tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
                tokens += 1
            jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
    return tokens, dt


def bench_kernel(cfg, acfg, batch):
    """Grouped kernel (interpret mode, CPU) vs jnp reference — parity
    record, not a hot path on this backend."""
    from repro.kernels import ops, ref
    K = N = max(128, cfg.d_model)
    r = acfg.rank
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    M = max(8, batch)
    x = jax.random.normal(ks[0], (M, K), jnp.float32)
    w = jax.random.normal(ks[1], (K, N), jnp.float32) * 0.05
    a = jax.random.normal(ks[2], (K, r), jnp.float32) * 0.05
    bs = jax.random.normal(ks[3], (batch, r, N), jnp.float32) * 0.05
    sid = jax.random.randint(ks[4], (M,), 0, batch)
    y = ops.bgmv(x, w, a, bs, sid, acfg.scaling, bm=M, bn=128, bk=128)
    y0 = ref.bgmv_ref(x, w, a, bs, sid, acfg.scaling)
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                - y0.astype(jnp.float32))))
    emit("serving.bgmv_kernel_max_err", 0.0, f"{err:.2e}")
    assert err < 1e-4, err
    return err


def _engine_row(rep):
    """The machine-readable slice of an engine report (``write_record``
    nulls any non-finite float at serialization time)."""
    keys = ("tok_per_s", "gen_tok_per_s", "decode_tok_per_s",
            "prefill_tokens", "decode_tokens", "generated_tokens",
            "decode_steps", "prefill_batches", "prefill_retraces",
            "decode_retraces", "batch_occupancy", "page_utilization",
            "pool_occupancy", "adapter_hit_rate", "wall_s", "kv_layout")
    row = {k: rep[k] for k in keys if k in rep}
    row["latency"] = latency_row(rep)
    return row


def main(clients=8, batch=8, requests=16, new_tokens=24, page_size=16,
         max_seq=256, out=None):
    """Both engines get the same ``max_seq`` admission capacity — the
    dense layout must allocate (and attend over) all of it for every
    row, while the paged engine's cost follows the traffic actually
    served. That equal-capacity framing is the paging claim."""
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=128)
    acfg = AdapterConfig(mode="fedsa", rank=8)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg, jnp.float32)
    template = {"adapters": init_adapters(key, cfg, acfg)}
    client_trees = [t["adapters"] for t in
                    synthetic_clients(template, clients, seed=11)]
    base = template["adapters"]
    # heterogeneous prompt lengths: short chats to long contexts
    hetero = [8, 24, 12, 48, 6, 32, 16, 40]
    lens = [hetero[i % len(hetero)] for i in range(requests)]
    assert max(lens) + new_tokens <= max_seq
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in lens]

    common = (cfg, params, acfg, base, client_trees, prompts, new_tokens,
              batch, max_seq)
    paged = run_engine(*common, kv_layout="paged", page_size=page_size)
    dense = run_engine(*common, kv_layout="dense")
    nv_tokens, nv_dt = run_naive(cfg, params, acfg, client_trees, prompts,
                                 new_tokens, max_seq)
    nv_tps = nv_tokens / nv_dt

    speedup = paged["gen_tok_per_s"] / dense["gen_tok_per_s"]
    decode_speedup = paged["decode_tok_per_s"] / dense["decode_tok_per_s"]
    emit("serving.paged_gen_tok_per_s", 1e6 / paged["gen_tok_per_s"],
         f"{paged['gen_tok_per_s']:.1f}")
    emit("serving.dense_gen_tok_per_s", 1e6 / dense["gen_tok_per_s"],
         f"{dense['gen_tok_per_s']:.1f}")
    emit("serving.naive_sequential_tok_per_s", nv_dt / nv_tokens * 1e6,
         f"{nv_tps:.1f}")
    emit("serving.paged_speedup_vs_dense", 0.0, f"{speedup:.2f}x")
    emit("serving.paged_decode_speedup_vs_dense", 0.0,
         f"{decode_speedup:.2f}x")
    emit("serving.prefill_batches", 0.0,
         f"{paged['prefill_batches']}v{dense['prefill_batches']}")
    emit("serving.page_utilization", 0.0,
         f"{paged['page_utilization']:.2f}")
    emit("serving.batch_occupancy", 0.0, f"{paged['batch_occupancy']:.2f}")
    emit("serving.adapter_hit_rate", 0.0,
         f"{paged['adapter_hit_rate']:.2f}")
    if paged.get("ttft_p50_s") is not None:
        emit("serving.paged_ttft_p50_us", paged["ttft_p50_s"] * 1e6,
             f"p99 {paged['ttft_p99_s']*1e3:.2f}ms")
        emit("serving.paged_e2e_p50_us", paged["e2e_p50_s"] * 1e6,
             f"p99 {paged['e2e_p99_s']*1e3:.2f}ms")
    kerr = bench_kernel(cfg, acfg, batch)

    bench_path = BENCH_PATH if out is None else pathlib.Path(out)
    record = {
        "bench": "serving_throughput",
        "config": {"arch": cfg.name, "n_layers": cfg.n_layers,
                   "d_model": cfg.d_model, "rank": acfg.rank,
                   "clients": clients, "batch": batch,
                   "requests": requests, "prompt_lens": lens,
                   "new_tokens": new_tokens, "max_seq": max_seq,
                   "page_size": page_size, "backend":
                   jax.default_backend()},
        "paged": _engine_row(paged),
        "dense": _engine_row(dense),
        "naive": {"tok_per_s": nv_tps, "wall_s": nv_dt},
        "speedup_vs_dense": speedup,
        "decode_speedup_vs_dense": decode_speedup,
        "speedup_vs_naive": paged["gen_tok_per_s"] / nv_tps,
        "bgmv_kernel_max_err": kerr,
    }
    write_record(bench_path, record)
    print(f"paged {paged['gen_tok_per_s']:.1f} gen tok/s vs dense "
          f"{dense['gen_tok_per_s']:.1f} vs naive {nv_tps:.1f} → "
          f"{speedup:.2f}x over dense ({decode_speedup:.2f}x decode-only) "
          f"at {requests} heterogeneous requests / batch {batch} "
          f"[{bench_path.name}]")
    return record


def _cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256,
                    help="admission capacity shared by both engines")
    ap.add_argument("--out", default=None,
                    help="write the JSON record here instead of the "
                         "committed BENCH_serving.json (CI keeps the "
                         "baseline intact for the regression gate)")
    a = ap.parse_args()
    main(clients=a.clients, batch=a.batch, requests=a.requests,
         new_tokens=a.new_tokens, page_size=a.page_size,
         max_seq=a.max_seq, out=a.out)


if __name__ == "__main__":
    _cli()
