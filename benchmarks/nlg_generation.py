"""§5.3 NLG proxy: federated next-token prediction on client-flavoured
Markov chains (GSM8K/CodeSearchNet stand-in). Metric: held-out LM loss
(lower = better), per-client personalized eval."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import AdapterConfig, FedConfig, get_config, reduced
from repro.core import federation
from repro.data.synthetic import make_lm_task


def main(rounds=30):
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=128)
    clients, tests = make_lm_task(n_clients=3, vocab=cfg.vocab_size, seq=32,
                                  n_train=384, n_test=96,
                                  hetero_strength=0.4, seed=0)
    test_batch = {k: jnp.asarray(np.stack([t[k][:32] for t in tests]))
                  for k in tests[0]}
    fed = FedConfig(n_clients=3, local_steps=5)
    out = {}
    for mode in ["fedavg", "ffa", "fedsa"]:
        acfg = AdapterConfig(mode=mode, rank=8)
        sys = federation.build(jax.random.PRNGKey(0), cfg, acfg, fed,
                               task="lm", lr=5e-2)
        hist = federation.run_rounds(sys, clients, rounds=rounds,
                                     batch_size=16, seed=1)
        test_loss = float(jnp.mean(sys.eval_fn(sys.trainables, test_batch)))
        out[mode] = test_loss
        emit(f"nlg/{mode}", 0, f"test_lm_loss={test_loss:.4f};"
             f"train_loss={hist['loss'][-1]:.4f}")
    return out


if __name__ == "__main__":
    main()
