"""Fused on-device multi-tick decode vs the per-tick baseline.

The PR-5 claim: the paged serving engine's decode throughput is bounded
by dispatch overhead, not kernels — ``step()`` pays one full
host↔device round trip (dispatch + sync + scheduler bookkeeping) per
generated token. ``decode_backend="fused"`` runs up to T decode ticks
inside ONE jitted ``lax.scan`` (greedy sampling, position advance,
per-row budget/EOS masking, and the page-pool commit all on device), so
the host syncs once per T tokens instead of once per token.

Arms, same model / prompts / greedy decode, warmed jit caches, all on
the paged layout:

  pertick    decode_backend="per-tick" — the PR-2..4 engine
  fused@T    decode_backend="fused" for each T in ``--ticks``

Every arm is token-parity-checked against the per-tick baseline before
its timing counts (a fused engine that drifts is a bug, not a speedup).
The headline metric is decode-only tok/s at T=8 over per-tick
(``speedup_vs_pertick``). Results → ``BENCH_decode.json``.

  PYTHONPATH=src python benchmarks/serving_decode_fused.py \
      [--requests 16] [--new-tokens 24] [--ticks 1,4,8,16]
"""
from __future__ import annotations

import argparse
import pathlib

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import init_model
from repro.serving.demo import synthetic_clients

try:                       # python -m benchmarks.serving_decode_fused / run.py
    from benchmarks.common import emit, latency_row, write_record
    from benchmarks.serving_throughput import run_engine
except ImportError:        # python benchmarks/serving_decode_fused.py
    from common import emit, latency_row, write_record
    from serving_throughput import run_engine

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_decode.json"

GATED_TICKS = 8            # the acceptance T (ISSUE 5: >=1.5x at T=8)


def _row(rep):
    keys = ("tok_per_s", "gen_tok_per_s", "decode_tok_per_s",
            "decode_tokens", "decode_steps", "decode_retraces",
            "host_syncs", "host_syncs_per_token", "fused_scans",
            "fused_ticks_mean", "fused_tick_shrinks",
            "pages_window_reserved", "pages_window_used",
            "batch_occupancy", "wall_s", "decode_backend", "decode_ticks")
    row = {k: rep[k] for k in keys if k in rep}
    row["latency"] = latency_row(rep)
    return row


def main(clients=8, batch=8, requests=16, new_tokens=24, page_size=16,
         max_seq=256, ticks=(1, 4, 8, 16), out=None):
    """Same model/workload shape as ``serving_throughput`` (the
    BENCH_serving workload) so the two records compose: this benchmark
    isolates decode, holding layout (paged), prefill, and scheduling
    fixed while only the decode dispatch granularity varies."""
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=128)
    acfg = AdapterConfig(mode="fedsa", rank=8)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg, jnp.float32)
    template = {"adapters": init_adapters(key, cfg, acfg)}
    client_trees = [t["adapters"] for t in
                    synthetic_clients(template, clients, seed=11)]
    base = template["adapters"]
    hetero = [8, 24, 12, 48, 6, 32, 16, 40]
    lens = [hetero[i % len(hetero)] for i in range(requests)]
    assert max(lens) + new_tokens <= max_seq
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in lens]

    common = (cfg, params, acfg, base, client_trees, prompts, new_tokens,
              batch, max_seq)

    def arm(**kw):
        rep = run_engine(*common, kv_layout="paged", page_size=page_size,
                         keep_engine=True, **kw)
        return rep, rep.pop("_engine")

    pertick_rep, pertick_eng = arm()
    want = {r: pertick_eng.finished[r]["tokens"].tolist()
            for r in pertick_eng.finished}
    fused = {}
    for T in ticks:
        rep, eng = arm(decode_backend="fused", decode_ticks=T)
        got = {r: eng.finished[r]["tokens"].tolist() for r in eng.finished}
        assert got == want, f"fused T={T} broke token parity"
        fused[T] = rep
        emit(f"serving.fused_t{T}_decode_tok_per_s",
             1e6 / rep["decode_tok_per_s"],
             f"{rep['decode_tok_per_s']:.1f}")

    emit("serving.pertick_decode_tok_per_s",
         1e6 / pertick_rep["decode_tok_per_s"],
         f"{pertick_rep['decode_tok_per_s']:.1f}")
    by_ticks = {T: fused[T]["decode_tok_per_s"]
                / pertick_rep["decode_tok_per_s"] for T in ticks}
    gate_T = GATED_TICKS if GATED_TICKS in by_ticks else max(by_ticks)
    speedup = by_ticks[gate_T]
    for T, s in by_ticks.items():
        emit(f"serving.fused_t{T}_speedup_vs_pertick", 0.0, f"{s:.2f}x")
    emit("serving.fused_host_syncs_per_token", 0.0,
         f"{fused[gate_T]['host_syncs_per_token']:.3f}")

    bench_path = BENCH_PATH if out is None else pathlib.Path(out)
    record = {
        "bench": "serving_decode_fused",
        "config": {"arch": cfg.name, "n_layers": cfg.n_layers,
                   "d_model": cfg.d_model, "rank": acfg.rank,
                   "clients": clients, "batch": batch,
                   "requests": requests, "prompt_lens": lens,
                   "new_tokens": new_tokens, "max_seq": max_seq,
                   "page_size": page_size, "ticks": list(ticks),
                   "gated_ticks": gate_T,
                   "backend": jax.default_backend()},
        "pertick": _row(pertick_rep),
        "fused": {str(T): _row(r) for T, r in fused.items()},
        "decode_speedup_by_ticks": {str(T): s for T, s in by_ticks.items()},
        "speedup_vs_pertick": speedup,
    }
    write_record(bench_path, record)
    sweep = " ".join(f"T={T}:{s:.2f}x" for T, s in by_ticks.items())
    print(f"fused decode {fused[gate_T]['decode_tok_per_s']:.1f} tok/s at "
          f"T={gate_T} vs per-tick {pertick_rep['decode_tok_per_s']:.1f} → "
          f"{speedup:.2f}x decode-only ({sweep}) [{bench_path.name}]")
    return record


def _cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--ticks", default="1,4,8,16",
                    help="comma-separated fused tick counts to sweep")
    ap.add_argument("--out", default=None,
                    help="write the JSON record here instead of the "
                         "committed BENCH_decode.json (CI keeps the "
                         "baseline intact for the regression gate)")
    a = ap.parse_args()
    main(clients=a.clients, batch=a.batch, requests=a.requests,
         new_tokens=a.new_tokens, page_size=a.page_size, max_seq=a.max_seq,
         ticks=tuple(int(t) for t in a.ticks.split(",")), out=a.out)


if __name__ == "__main__":
    _cli()
