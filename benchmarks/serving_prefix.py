"""Prefix-cache benchmark: copy-on-write prefix reuse vs full prefill
(PR 10 acceptance).

Two engine arms serve the SAME workload — a fleet of prompts sharing a
multi-chunk system prefix with short divergent suffixes, the agent /
chat-template traffic shape prefix caching exists for — differing only
in ``ServingConfig(prefix_cache=...)``:

  prefill throughput  both arms run a prefill-dominated pass
                      (``max_new_tokens=1``) twice, warm then timed; the
                      timed pass sums the ``prefill_batch`` trace
                      events' wall and reports prompt tokens per prefill
                      second. The cache-on arm prefills only divergent
                      suffixes after its donor wave, so
                      ``prefill_speedup`` (on ÷ off) is gated >= 1.5 in
                      CI (acceptance target >= 2x).

  max concurrency     both arms drive a decode workload through a pool
                      deliberately too small for the fleet
                      (``n_pages`` fixed) and track the peak number of
                      concurrently active sequences. Sharing the prefix
                      pages once instead of per-row fits more rows into
                      the same pool: ``concurrency_ratio`` (on ÷ off,
                      acceptance >= 1.5x). The decode workload's output
                      tokens are asserted identical across the arms —
                      prefix reuse + CoW must be invisible in tokens.

  PYTHONPATH=src python benchmarks/serving_prefix.py [--requests 24]
"""
from __future__ import annotations

import argparse
import pathlib

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import init_model
from repro.obs import TraceLog
from repro.serving import AdapterRegistry, ServingConfig, ServingEngine
from repro.serving.demo import synthetic_clients

try:
    from benchmarks.common import emit, write_record
except ImportError:        # python benchmarks/serving_prefix.py
    from common import emit, write_record

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_prefix.json"

KEY = jax.random.PRNGKey(0)


def build(n_clients=3):
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)
    acfg = AdapterConfig(mode="fedsa", rank=4)
    params = init_model(KEY, cfg, jnp.float32)
    base = init_adapters(KEY, cfg, acfg)
    trees = [t["adapters"] for t in
             synthetic_clients({"adapters": base}, n_clients, seed=50,
                               scale=0.05)]
    return cfg, acfg, params, base, trees


def make_engine(built, *, trace=None, **kw):
    cfg, acfg, params, base, trees = built
    reg = AdapterRegistry({"adapters": base}, n_slots=len(trees))
    for i, t in enumerate(trees):
        reg.ingest(i, {"adapters": t})
    return ServingEngine(cfg, params, acfg, reg, ServingConfig(**kw),
                         trace=trace)


def fleet_prompts(cfg, *, prefix_tokens, requests, suffix_max=16, seed=3):
    """``requests`` prompts sharing one system prefix, suffix lengths
    cycling 1..suffix_max (so every prefill bucket gets traffic)."""
    rng = np.random.default_rng(seed)
    head = rng.integers(0, cfg.vocab_size, prefix_tokens)
    return [np.concatenate([head,
                            rng.integers(0, cfg.vocab_size,
                                         1 + i % suffix_max)])
            for i in range(requests)]


def prefill_wall(trace, start):
    return sum(e["wall_s"] for e in trace.events[start:]
               if e["ev"] == "prefill_batch")


def run_prefill_arm(built, prompts, *, prefix_cache, batch, max_seq,
                    page_size, chunk_pages):
    """Prefill-dominated pass (1 token per request): prompt tokens per
    second of prefill wall, timed after a warm pass so neither compile
    time nor a cold cache pollutes the measurement."""
    tr = TraceLog()
    eng = make_engine(built, trace=tr, max_batch=batch, max_seq=max_seq,
                      kv_layout="paged", page_size=page_size,
                      prefix_cache=prefix_cache,
                      prefix_chunk_pages=chunk_pages)
    submitted = sum(len(p) for p in prompts)
    stats = {}
    # two warm passes: the first populates the cache (and compiles the
    # full-prefill buckets), the second runs all-hits and compiles the
    # suffix buckets — so the timed pass measures steady state, not jit
    for timed in (False, False, True):
        eng.reset_stats()
        start = len(tr.events)
        for i, p in enumerate(prompts):
            eng.submit(i % 3, p, max_new_tokens=1)
        rep = eng.run()
        wall = prefill_wall(tr, start)
        stats = {
            # effective throughput: tokens the caller handed us per
            # second of prefill wall — cached tokens cost ~nothing, so
            # this is where the cache shows up
            "prompt_tokens": submitted,
            "prefill_tokens_run": rep["prefill_tokens"],
            "prefill_wall_s": wall,
            "prefill_tok_per_s": submitted / wall,
            "prefill_batches": rep["prefill_batches"],
            "prefix_hits": rep["prefix_hits"],
            "prefix_hit_rate": rep["prefix_hit_rate"],
            "prefix_hit_tokens": rep["prefix_hit_tokens"],
        }
    tokens = {r: eng.finished[r]["tokens"].tolist() for r in eng.finished}
    return stats, tokens


def run_concurrency_arm(built, prompts, *, prefix_cache, batch, max_seq,
                        page_size, chunk_pages, n_pages, new_tokens):
    """Decode workload through a fixed undersized pool: peak concurrent
    sequences + full output tokens (the cross-arm parity evidence)."""
    eng = make_engine(built, max_batch=batch, max_seq=max_seq,
                      kv_layout="paged", page_size=page_size,
                      n_pages=n_pages, prefix_cache=prefix_cache,
                      prefix_chunk_pages=chunk_pages)
    # one tenant: the cache namespaces prefixes per adapter tag, so a
    # shared system prompt only amortizes pages within a client
    for i, p in enumerate(prompts):
        eng.submit(0, p, max_new_tokens=new_tokens)
    peak, steps = 0, 0
    while not eng.scheduler.idle and steps < 10_000:
        eng.step()
        peak = max(peak, len(eng.scheduler.active))
        steps += 1
    rep = eng.report()
    assert rep["requests"] == len(prompts), "workload did not drain"
    tokens = {r: eng.finished[r]["tokens"].tolist() for r in eng.finished}
    return {
        "peak_concurrency": peak,
        "pages_shared": rep["pages_shared"],
        "cow_copies": rep["cow_copies"],
        "prefix_hits": rep["prefix_hits"],
        "prefix_evictions": rep["prefix_evictions"],
        "decode_tokens": rep["decode_tokens"],
    }, tokens


def main(requests=24, batch=8, max_seq=512, page_size=16, chunk_pages=1,
         prefix_tokens=448, new_tokens=8, n_pages=72, out=None):
    built = build()
    cfg = built[0]
    prompts = fleet_prompts(cfg, prefix_tokens=prefix_tokens,
                            requests=requests)

    pre_off, tok_off = run_prefill_arm(
        built, prompts, prefix_cache=False, batch=batch, max_seq=max_seq,
        page_size=page_size, chunk_pages=chunk_pages)
    pre_on, tok_on = run_prefill_arm(
        built, prompts, prefix_cache=True, batch=batch, max_seq=max_seq,
        page_size=page_size, chunk_pages=chunk_pages)
    assert tok_on == tok_off, "prefix cache changed prefill tokens"
    speedup = pre_on["prefill_tok_per_s"] / pre_off["prefill_tok_per_s"]
    emit("prefix/prefill_off_tok_per_s", pre_off["prefill_tok_per_s"],
         "cache off")
    emit("prefix/prefill_on_tok_per_s", pre_on["prefill_tok_per_s"],
         f"hit_rate={pre_on['prefix_hit_rate']:.3f}")
    emit("prefix/prefill_speedup", 0.0, f"{speedup:.2f}x")

    conc_off, dtok_off = run_concurrency_arm(
        built, prompts, prefix_cache=False, batch=batch, max_seq=max_seq,
        page_size=page_size, chunk_pages=chunk_pages, n_pages=n_pages,
        new_tokens=new_tokens)
    conc_on, dtok_on = run_concurrency_arm(
        built, prompts, prefix_cache=True, batch=batch, max_seq=max_seq,
        page_size=page_size, chunk_pages=chunk_pages, n_pages=n_pages,
        new_tokens=new_tokens)
    # the in-bench token-parity gate: same prompts, same adapters, same
    # pool → byte-identical outputs whether or not pages were shared
    assert dtok_on == dtok_off, "prefix cache changed decode tokens"
    ratio = conc_on["peak_concurrency"] / conc_off["peak_concurrency"]
    emit("prefix/concurrency_off", conc_off["peak_concurrency"],
         "cache off")
    emit("prefix/concurrency_on", conc_on["peak_concurrency"],
         f"cow_copies={conc_on['cow_copies']}")
    emit("prefix/concurrency_ratio", 0.0, f"{ratio:.2f}x")

    record = {
        "bench": "serving_prefix",
        "config": {
            "arch": "deepseek-7b", "n_layers": cfg.n_layers,
            "d_model": cfg.d_model, "rank": built[1].rank,
            "clients": 3, "batch": batch, "requests": requests,
            "new_tokens": new_tokens, "max_seq": max_seq,
            "page_size": page_size, "n_pages": n_pages,
            "prefix_chunk_pages": chunk_pages,
            "prefix_tokens": prefix_tokens,
        },
        "prefill_off": pre_off,
        "prefill_on": pre_on,
        "prefill_speedup": speedup,
        "concurrency_off": conc_off,
        "concurrency_on": conc_on,
        "concurrency_ratio": ratio,
        "token_parity": True,
    }
    path = write_record(out or BENCH_PATH, record)
    print(f"# wrote {path}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--chunk-pages", type=int, default=1)
    ap.add_argument("--prefix-tokens", type=int, default=448)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--n-pages", type=int, default=72)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    main(requests=args.requests, batch=args.batch, max_seq=args.max_seq,
         page_size=args.page_size, chunk_pages=args.chunk_pages,
         prefix_tokens=args.prefix_tokens, new_tokens=args.new_tokens,
         n_pages=args.n_pages, out=args.out)
