"""Fig. 2 (+ Figs 5, 6): cross-client similarity of learned A vs B matrices
under increasing heterogeneity, after LOCAL-ONLY fine-tuning.

Claims reproduced:
  (i)   sim(A) > sim(B) across clients, for LoRA, rsLoRA AND VeRA;
  (ii)  sim(B) decreases as heterogeneity increases;
  (iii) A moves away from its init (Fig. 4 — the updates are real).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, encoder_cfg, make_task
from repro.configs import AdapterConfig, FedConfig
from repro.core import federation
from repro.core.similarity import pairwise_similarity, update_similarity
from repro.data.synthetic import stack_client_batch

SPLITS = [("iid", None, 0.1), ("dir1", 1.0, 0.35), ("dir0.5", 0.5, 0.6)]


def local_train(variant, alpha, hetero, rounds=25, seed=0):
    cfg = encoder_cfg()
    clients, _ = make_task(3, alpha, seed=seed, hetero_strength=hetero)
    fed = FedConfig(n_clients=3, local_steps=5)
    acfg = AdapterConfig(mode="fedsa", variant=variant, rank=8, vera_rank=32)
    lr = 2e-3 if variant == "vera" else 5e-2
    sys = federation.build(jax.random.PRNGKey(seed), cfg, acfg, fed,
                           task="classification", n_classes=4, lr=lr)
    init_ad = jax.tree_util.tree_map(lambda x: x[0],
                                     sys.trainables["adapters"])
    tr, ost = sys.trainables, sys.opt_state
    rng = np.random.default_rng(seed + 1)
    part = jnp.zeros((3,), jnp.float32)        # no aggregation: local only
    for _ in range(rounds):
        steps = [stack_client_batch(clients, 16, rng) for _ in range(5)]
        batches = {k: jnp.asarray(np.stack([s[k] for s in steps], 1))
                   for k in steps[0]}
        tr, ost, _ = sys.round_fn(tr, ost, batches, part)
    sims = pairwise_similarity(tr["adapters"])
    upd = update_similarity(tr["adapters"], init_ad)
    return sims, upd


def main(rounds=25):
    out = {}
    for variant in ["lora", "rslora", "vera"]:
        a_name, b_name = ("d", "b") if variant == "vera" else ("A", "B")
        for split, alpha, hetero in SPLITS:
            sims, upd = local_train(variant, alpha, hetero, rounds=rounds)
            out[(variant, split)] = {"sim": sims, "update_sim": upd}
            emit(f"fig2/{variant}/{split}", 0,
                 f"simA={sims[a_name]:.4f};simB={sims[b_name]:.4f};"
                 f"A_vs_init={upd[a_name]:.4f}")
    return out


if __name__ == "__main__":
    main()
