"""CI regression gate for the serving benchmark.

Compares a fresh ``serving_throughput.py --out BENCH_fresh.json`` run
against the committed ``BENCH_serving.json`` baseline. The gate fails
(exit 1) when the paged engine regresses:

  * hard floor: paged must stay at least ``--floor`` (default 1.0×) as
    fast as the dense engine — paging that loses to dense is a bug, not
    noise;
  * baseline band: the fresh paged-vs-dense speedup must stay within
    ``--tolerance`` (default 0.5, i.e. 50%) of the committed baseline —
    wide because the CI smoke run is tiny (2 requests) and shared
    runners are noisy, tight enough to catch a real collapse.

``--invert`` flips the verdict — used once locally to prove the gate
actually trips on a synthetic regression (ISSUE 3 acceptance).

  PYTHONPATH=src python benchmarks/bench_gate.py \
      --fresh BENCH_fresh.json [--baseline BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


# a baseline-band comparison only means something when both records ran
# the same workload; otherwise the hard floor is the whole gate
_WORKLOAD_KEYS = ("arch", "n_layers", "d_model", "rank", "clients",
                  "batch", "requests", "new_tokens", "max_seq",
                  "page_size")


def evaluate(fresh, baseline, *, floor=1.0, tolerance=0.5):
    """(ok, lines) verdict for a fresh record vs the committed baseline."""
    got = fresh["speedup_vs_dense"]
    ref = baseline["speedup_vs_dense"]
    lines = [
        f"paged-vs-dense speedup: fresh {got:.3f}x, baseline {ref:.3f}x",
        f"hard floor {floor:.2f}x: {'ok' if got >= floor else 'FAIL'}",
    ]
    fc, bc = fresh.get("config", {}), baseline.get("config", {})
    same = all(fc.get(k) == bc.get(k) for k in _WORKLOAD_KEYS)
    if same:
        band = ref * (1.0 - tolerance)
        lines.append(
            f"baseline band >= {band:.3f}x (tolerance {tolerance:.0%}): "
            f"{'ok' if got >= band else 'FAIL'}")
    else:
        band = None
        diff = [k for k in _WORKLOAD_KEYS if fc.get(k) != bc.get(k)]
        lines.append(
            f"baseline band skipped: workload differs from baseline "
            f"({', '.join(diff)}) — hard floor only")
    return got >= floor and (band is None or got >= band), lines


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="JSON written by serving_throughput.py --out")
    ap.add_argument("--baseline",
                    default=str(REPO / "BENCH_serving.json"))
    ap.add_argument("--floor", type=float, default=1.0)
    ap.add_argument("--tolerance", type=float, default=0.5)
    ap.add_argument("--invert", action="store_true",
                    help="fail when the gate would pass (local check "
                         "that the gate trips on a regression)")
    args = ap.parse_args(argv)
    fresh = json.loads(pathlib.Path(args.fresh).read_text())
    baseline = json.loads(pathlib.Path(args.baseline).read_text())
    ok, lines = evaluate(fresh, baseline, floor=args.floor,
                         tolerance=args.tolerance)
    for line in lines:
        print(line)
    if args.invert:
        ok = not ok
        print(f"inverted verdict: {'pass' if ok else 'FAIL'}")
    print("bench gate:", "pass" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
