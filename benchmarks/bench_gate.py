"""CI regression gate for the serving benchmarks.

Compares a fresh ``--out``-written benchmark record against the
committed baseline JSON at the repo root. The record's ``bench`` field
picks the gated metric:

  serving_throughput  ``speedup_vs_dense``  — the paged engine vs the
                      dense fallback (baseline ``BENCH_serving.json``)
  serving_refresh     ``speedup_vs_drain``  — live absorb vs
                      drain-and-rebuild (baseline ``BENCH_refresh.json``)
  serving_sgmv        ``speedup_vs_perclient`` — grouped personal-A
                      serving vs the sequential per-client loop
                      (baseline ``BENCH_sgmv.json``)
  serving_decode_fused ``speedup_vs_pertick`` — fused multi-tick decode
                      at the gated tick count vs the per-tick engine
                      (baseline ``BENCH_decode.json``)
  serving_tiering     ``admission_speedup`` — tiered (host ring +
                      prefetch) p99 admission vs evict-and-reingest
                      from cold (baseline ``BENCH_tiering.json``)
  serving_prefix      ``prefill_speedup`` — shared-prefix fleet with
                      the CoW prefix cache vs full per-row prefill
                      (baseline ``BENCH_prefix.json``)

The gate fails (exit 1) when the fresh metric regresses:

  * hard floor: the fresh speedup must stay at least ``--floor`` —
    defaults per bench (1.0× for throughput, where paging that loses to
    dense is a bug; lower for refresh/sgmv smoke runs, whose tiny
    CI workloads amortize less fixed cost);
  * baseline band: the fresh speedup must stay within ``--tolerance``
    (default 0.5, i.e. 50%) of the committed baseline — wide because CI
    smoke runs are small and shared runners are noisy, tight enough to
    catch a real collapse. Skipped (hard floor only) when the fresh
    run's workload config differs from the baseline's.

``--invert`` flips the verdict — used once locally to prove the gate
actually trips on a synthetic regression (ISSUE 3 acceptance).

  PYTHONPATH=src python benchmarks/bench_gate.py \
      --fresh BENCH_fresh.json [--baseline BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

_COMMON_KEYS = ("arch", "n_layers", "d_model", "rank", "clients", "batch",
                "requests", "new_tokens", "max_seq")

# per-bench gate spec: metric key, extra workload keys that must match
# for the baseline band to mean anything, default hard floor, and the
# committed baseline file
_BENCHES = {
    "serving_throughput": {
        "metric": "speedup_vs_dense",
        "workload": _COMMON_KEYS + ("page_size",),
        "floor": 1.0,
        "baseline": "BENCH_serving.json",
    },
    "serving_refresh": {
        "metric": "speedup_vs_drain",
        "workload": _COMMON_KEYS + ("rounds",),
        # the live-vs-drain edge shrinks with workload size (rebuild
        # cost amortizes over fewer tokens) and is the noisiest of the
        # gated ratios on shared runners — floor well under the ~1.24×
        # committed baseline, the band does the real work
        "floor": 0.5,
        "baseline": "BENCH_refresh.json",
    },
    "serving_sgmv": {
        "metric": "speedup_vs_perclient",
        "workload": _COMMON_KEYS + ("page_size",),
        # acceptance floor from ISSUE 4 (≥1.5× over the per-client loop
        # at 8 personal-A clients), relaxed for runner variance
        "floor": 1.2,
        "baseline": "BENCH_sgmv.json",
    },
    "serving_decode_fused": {
        "metric": "speedup_vs_pertick",
        "workload": _COMMON_KEYS + ("page_size", "ticks"),
        # acceptance floor from ISSUE 5 (≥1.5× decode-only at T=8 over
        # the per-tick engine), relaxed for runner variance — the fused
        # loop's edge IS dispatch overhead, which shared runners vary
        "floor": 1.2,
        "baseline": "BENCH_decode.json",
    },
    "serving_tiering": {
        # baseline p99 admission latency ÷ tiered p99 over the same
        # Zipf(1.0) trace at equal HBM slot count — how much the host
        # ring + prefetch lookahead beat evict-and-reingest-from-cold
        "metric": "admission_speedup",
        "workload": _COMMON_KEYS + ("n_slots", "host_ring_slots",
                                    "zipf_a", "accesses", "lookahead"),
        # ISSUE 8 acceptance: tiered p99 ≤ 0.5× the cold-reingest
        # baseline (speedup ≥ 2×); the committed record runs well above
        "floor": 2.0,
        "baseline": "BENCH_tiering.json",
    },
    "serving_prefix": {
        # prompt tokens per second of prefill wall, cache-on ÷ cache-off
        # over the same shared-prefix fleet — the cache-on arm prefills
        # only divergent suffixes, so its edge scales with the prefix
        # share of the prompt. ISSUE 10 acceptance: ≥2×; floor relaxed
        # for runner variance (committed record runs >20×). The bench
        # itself hard-asserts cross-arm token parity before writing a
        # record, so a passing gate also certifies parity held
        "metric": "prefill_speedup",
        "workload": _COMMON_KEYS + ("page_size", "n_pages",
                                    "prefix_chunk_pages",
                                    "prefix_tokens"),
        "floor": 1.5,
        "baseline": "BENCH_prefix.json",
    },
    "serving_sharded": {
        # (N, 1) data-sharded decode tok/s ÷ single-device decode tok/s
        # — on CI's forced host devices the shards share one CPU, so the
        # collectives and partitioned dispatch are pure overhead and the
        # ratio sits well under 1×. The bench asserts bit-identical
        # token parity in-process (it aborts before writing a record on
        # divergence); the gate's job is to catch a *collapse* — a
        # retrace storm or host-sync explosion on the sharded path —
        # not to demand speedup, hence the low floor
        "metric": "sharded_decode_ratio",
        "workload": _COMMON_KEYS + ("page_size", "mesh_data"),
        "floor": 0.05,
        "baseline": "BENCH_sharded.json",
    },
    "serving_chaos": {
        # faulted decode tok/s ÷ clean decode tok/s under the default
        # seeded fault profile — availability under chaos, not raw speed
        "metric": "faulted_decode_ratio",
        "workload": _COMMON_KEYS + ("page_size", "fault_seed"),
        # ISSUE 7 acceptance: ≥0.8× clean-run decode throughput while
        # every admitted request completes or is explicitly shed
        "floor": 0.8,
        "baseline": "BENCH_chaos.json",
    },
}


def evaluate(fresh, baseline, *, floor=None, tolerance=0.5):
    """(ok, lines) verdict for a fresh record vs the committed baseline."""
    bench = fresh.get("bench", "serving_throughput")
    spec = _BENCHES.get(bench)
    if spec is None:
        return False, [f"unknown bench {bench!r}: no gate spec"]
    if baseline.get("bench", "serving_throughput") != bench:
        return False, [
            f"bench mismatch: fresh {bench!r} vs baseline "
            f"{baseline.get('bench')!r} — wrong --baseline file?"]
    metric = spec["metric"]
    floor = spec["floor"] if floor is None else floor
    got = fresh.get(metric)
    ref = baseline.get(metric)
    # records emit null (never NaN) for undefined metrics — a null gated
    # metric is an explicit FAIL with a message, not a TypeError
    if not isinstance(got, (int, float)):
        return False, [f"{bench} {metric}: fresh value is {got!r} "
                       f"(degenerate run?) — FAIL"]
    if not isinstance(ref, (int, float)):
        return False, [f"{bench} {metric}: baseline value is {ref!r} — "
                       f"regenerate the committed baseline — FAIL"]
    lines = [
        f"{bench} {metric}: fresh {got:.3f}x, baseline {ref:.3f}x",
        f"hard floor {floor:.2f}x: {'ok' if got >= floor else 'FAIL'}",
    ]
    fc, bc = fresh.get("config", {}), baseline.get("config", {})
    same = all(fc.get(k) == bc.get(k) for k in spec["workload"])
    if same:
        band = ref * (1.0 - tolerance)
        lines.append(
            f"baseline band >= {band:.3f}x (tolerance {tolerance:.0%}): "
            f"{'ok' if got >= band else 'FAIL'}")
    else:
        band = None
        diff = [k for k in spec["workload"] if fc.get(k) != bc.get(k)]
        lines.append(
            f"baseline band skipped: workload differs from baseline "
            f"({', '.join(diff)}) — hard floor only")
    return got >= floor and (band is None or got >= band), lines


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="JSON written by a serving benchmark's --out")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline record (default: the "
                         "bench-appropriate BENCH_*.json at the repo "
                         "root)")
    ap.add_argument("--floor", type=float, default=None,
                    help="hard floor override (default per bench)")
    ap.add_argument("--tolerance", type=float, default=0.5)
    ap.add_argument("--invert", action="store_true",
                    help="fail when the gate would pass (local check "
                         "that the gate trips on a regression)")
    args = ap.parse_args(argv)

    def reject_constant(c):
        raise ValueError(f"non-standard JSON constant {c} — benchmark "
                         "records must emit null, never NaN/Infinity")

    def load(path, role, bench=None):
        """Parse a record, failing with the file AND the bench spec it
        was supposed to satisfy instead of a raw traceback."""
        spec_note = (f" (expected record for bench {bench!r}, "
                     f"metric {_BENCHES[bench]['metric']!r})"
                     if bench in _BENCHES else "")
        try:
            text = pathlib.Path(path).read_text()
        except OSError as err:
            print(f"bench gate: FAIL — cannot read {role} record "
                  f"{path}{spec_note}: {err}")
            if role == "baseline":
                print("regenerate it with the matching benchmark's "
                      "--out and commit the JSON at the repo root")
            return None
        try:
            return json.loads(text, parse_constant=reject_constant)
        except ValueError as err:
            print(f"bench gate: FAIL — {role} record {path} is not "
                  f"valid JSON{spec_note}: {err}")
            return None

    fresh = load(args.fresh, "fresh")
    if fresh is None:
        return 1
    bench = fresh.get("bench", "serving_throughput")
    baseline_path = args.baseline
    if baseline_path is None:
        spec = _BENCHES.get(bench)
        if spec is None:
            print(f"unknown bench {bench!r}")
            return 1
        baseline_path = str(REPO / spec["baseline"])
    baseline = load(baseline_path, "baseline", bench=bench)
    if baseline is None:
        return 1
    ok, lines = evaluate(fresh, baseline, floor=args.floor,
                         tolerance=args.tolerance)
    for line in lines:
        print(line)
    if args.invert:
        ok = not ok
        print(f"inverted verdict: {'pass' if ok else 'FAIL'}")
    print("bench gate:", "pass" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
