"""Hierarchical adapter-store benchmark: tiered admission vs
evict-and-reingest-from-cold (PR 8 acceptance).

Two registry-level arms drive the SAME Zipf(1.0) access trace over a
256-tenant fleet whose HBM slot table holds only ``n_slots=16``
adapters, so the working set cannot stay device-resident:

  tiered    host_ring_slots=64 pinned-host ring over an npz cold store,
            plus admission-lookahead prefetch: before each access the
            next ``lookahead`` distinct queued tenants are promoted
            host-ward by the background prefetcher (the bench drains it
            between accesses — standing in for the decode step a real
            engine overlaps the promotion I/O with);
  baseline  host_ring_slots=0 over a second cold store — every HBM miss
            re-reads the adapter from npz inside ``acquire()``, the
            pre-tiering "evict and reingest" path at the SAME slot count.

The gated metric is ``admission_speedup`` = baseline p99 admission
latency ÷ tiered p99 (ISSUE 8 acceptance: tiered p99 ≤ 0.5× baseline,
i.e. speedup ≥ 2×), with the tiered arm's ``host_hit_rate`` (host hits
÷ non-resident admissions) required ≥ 0.8.

A third, engine-level arm answers "what does tiering cost when it isn't
needed": 16 tenants that all fit the slot table, decoded once on an
untiered engine and once with the tiered store + prefetcher enabled —
``allhot_decode_ratio`` (tiered ÷ untiered decode tok/s) must stay
within 5% of 1.0.

  PYTHONPATH=src python benchmarks/serving_tiering.py [--accesses 2000]
"""
from __future__ import annotations

import argparse
import pathlib
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import init_model
from repro.serving import AdapterRegistry, ServingConfig, ServingEngine
from repro.serving.demo import synthetic_clients

try:
    from benchmarks.common import emit, write_record
except ImportError:        # python benchmarks/serving_tiering.py
    from common import emit, write_record

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_tiering.json"

KEY = jax.random.PRNGKey(0)


def zipf_trace(n_clients, accesses, a=1.0, seed=0):
    """Zipf(a) tenant accesses: p(rank k) ∝ 1/k^a over ``n_clients``
    ranks, ranks scattered over client ids by a fixed permutation.
    numpy's ``zipf`` needs a>1, so the pmf is built by hand — a=1.0
    (the classic heavy tail) is exactly the regime the ISSUE gates."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_clients + 1, dtype=np.float64)
    p = ranks ** -a
    p /= p.sum()
    perm = rng.permutation(n_clients)            # rank -> client id
    return perm[rng.choice(n_clients, size=accesses, p=p)]


def run_admission(template, trees, trace, n_slots, *, host_ring_slots,
                  cold_dir, lookahead):
    """Drive ``trace`` through acquire/release on a fresh registry and
    return its admission samples + tier stats.

    With ``lookahead`` > 0 each access first requests prefetch for the
    next ``lookahead`` DISTINCT upcoming tenants, then drains the
    prefetcher — the drain models the decode step the engine overlaps
    promotion I/O with, so the acquire itself never pays the cold read."""
    reg = AdapterRegistry(template, n_slots=n_slots,
                          host_ring_slots=host_ring_slots,
                          cold_dir=cold_dir)
    for i, t in enumerate(trees):
        reg.ingest(i, t)
    # warm the code paths once (first-touch allocations, file cache)
    reg.acquire(int(trace[0]))
    reg.release(int(trace[0]))
    reg.reset_tier_stats()
    t0 = time.perf_counter()
    for i, cid in enumerate(trace):
        if lookahead:
            window, seen = trace[i + 1:i + 1 + 4 * lookahead], set()
            for nxt in window:
                if int(nxt) not in seen:
                    seen.add(int(nxt))
                    reg.prefetch(int(nxt))
                if len(seen) >= lookahead:
                    break
            reg.drain_prefetch()
        reg.acquire(int(cid))
        reg.release(int(cid))
    wall = time.perf_counter() - t0
    samples = np.array([s for _, s in reg.admission_samples])
    stats = reg.stats
    return {
        "admission_p50_us": float(np.percentile(samples, 50) * 1e6),
        "admission_p90_us": float(np.percentile(samples, 90) * 1e6),
        "admission_p99_us": float(np.percentile(samples, 99) * 1e6),
        "admission_mean_us": float(samples.mean() * 1e6),
        "wall_s": wall,
        "hbm_hit_rate": stats["hit_rate"],
        "host_hit_rate": stats["host_hit_rate"],
        "tier_host_hits": stats["tier_host_hits"],
        "tier_cold_misses": stats["tier_cold_misses"],
        "promotions": stats["promotions"],
        "demotions": stats["demotions"],
        "prefetches": stats["prefetches"],
        "tier_occupancy": stats["tier_occupancy"],
    }


def run_allhot(cfg, acfg, params, base, trees, *, batch, max_seq,
               requests, new_tokens, tiered):
    """All-hot engine arm: every tenant fits the slot table, so tiering
    machinery should be pure overhead — measure how much."""
    reg = AdapterRegistry({"adapters": base}, n_slots=len(trees))
    for i, t in enumerate(trees):
        reg.ingest(i, {"adapters": t})
    scfg = ServingConfig(max_batch=batch, max_seq=max_seq)
    if tiered:
        scfg = scfg.replace(host_ring_slots=2 * len(trees),
                            prefetch_lookahead=4)
    engine = ServingEngine(cfg, params, acfg, reg, scfg)
    for timed in (False, True):
        engine.reset_stats()
        rng = np.random.default_rng(11)
        for r in range(requests):
            engine.submit(r % len(trees),
                          rng.integers(0, cfg.vocab_size, 8),
                          max_new_tokens=new_tokens)
        rep = engine.run()
    return rep


def main(n_clients=256, n_slots=16, host_ring_slots=64, accesses=2000,
         lookahead=8, zipf_a=1.0, batch=4, requests=24, new_tokens=8,
         max_seq=32, out=None):
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)
    acfg = AdapterConfig(mode="fedsa", rank=4)

    base = init_adapters(KEY, cfg, acfg)
    template = {"adapters": base}
    trees = [{"adapters": t["adapters"]} for t in
             synthetic_clients(template, n_clients, seed=50, scale=0.05)]
    trace = zipf_trace(n_clients, accesses, a=zipf_a, seed=7)

    with tempfile.TemporaryDirectory() as cold_a, \
            tempfile.TemporaryDirectory() as cold_b:
        tiered = run_admission(template, trees, trace, n_slots,
                               host_ring_slots=host_ring_slots,
                               cold_dir=cold_a, lookahead=lookahead)
        baseline = run_admission(template, trees, trace, n_slots,
                                 host_ring_slots=0, cold_dir=cold_b,
                                 lookahead=0)

    speedup = baseline["admission_p99_us"] / tiered["admission_p99_us"]
    emit("tiering/tiered_p99", tiered["admission_p99_us"],
         f"host_hit_rate={tiered['host_hit_rate']:.3f}")
    emit("tiering/baseline_p99", baseline["admission_p99_us"],
         f"cold_misses={baseline['tier_cold_misses']}")
    emit("tiering/admission_speedup", 0.0, f"{speedup:.2f}x")

    params = init_model(KEY, cfg, jnp.float32)
    hot_trees = [t["adapters"] for t in trees[:n_slots]]
    rep_plain = run_allhot(cfg, acfg, params, base, hot_trees,
                           batch=batch, max_seq=max_seq,
                           requests=requests, new_tokens=new_tokens,
                           tiered=False)
    rep_tier = run_allhot(cfg, acfg, params, base, hot_trees,
                          batch=batch, max_seq=max_seq,
                          requests=requests, new_tokens=new_tokens,
                          tiered=True)
    ratio = (rep_tier["decode_tok_per_s"] / rep_plain["decode_tok_per_s"]
             if rep_plain["decode_tok_per_s"] else None)
    emit("tiering/allhot_decode_ratio", 0.0,
         f"{ratio:.3f}" if ratio is not None else "n/a")

    record = {
        "bench": "serving_tiering",
        "config": {
            "arch": "deepseek-7b", "n_layers": cfg.n_layers,
            "d_model": cfg.d_model, "rank": acfg.rank,
            "clients": n_clients, "batch": batch, "requests": requests,
            "new_tokens": new_tokens, "max_seq": max_seq,
            "n_slots": n_slots, "host_ring_slots": host_ring_slots,
            "zipf_a": zipf_a, "accesses": accesses,
            "lookahead": lookahead,
        },
        "tiered": tiered,
        "baseline": baseline,
        "admission_speedup": speedup,
        "host_hit_rate": tiered["host_hit_rate"],
        "allhot": {
            "untiered_decode_tok_per_s": rep_plain["decode_tok_per_s"],
            "tiered_decode_tok_per_s": rep_tier["decode_tok_per_s"],
        },
        "allhot_decode_ratio": ratio,
    }
    path = write_record(out or BENCH_PATH, record)
    print(f"# wrote {path}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=256)
    ap.add_argument("--n-slots", type=int, default=16)
    ap.add_argument("--host-ring-slots", type=int, default=64)
    ap.add_argument("--accesses", type=int, default=2000)
    ap.add_argument("--lookahead", type=int, default=8)
    ap.add_argument("--zipf-a", type=float, default=1.0)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    main(n_clients=args.clients, n_slots=args.n_slots,
         host_ring_slots=args.host_ring_slots, accesses=args.accesses,
         lookahead=args.lookahead, zipf_a=args.zipf_a,
         requests=args.requests, new_tokens=args.new_tokens,
         out=args.out)
