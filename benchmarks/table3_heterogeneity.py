"""Table 3: accuracy vs data heterogeneity (IID / Dir(1) / Dir(0.5)).

Claim: FedSA's edge over FedAvg-LoRA/FFA grows as heterogeneity grows.
"""
from __future__ import annotations

from benchmarks.common import emit, make_task, run_fl

# (split, dirichlet alpha, input-shift strength, concept shift). Split-1
# is a TRUE IID partition: no label skew, no vocab remap, no conflicting
# conditionals — the regime where the paper reports near-parity.
SPLITS = [("split1_iid", None, 0.0, 0.0), ("split2_dir1", 1.0, 0.35, 0.35),
          ("split3_dir0.5", 0.5, 0.5, 0.5)]


def main(rounds=60):
    out = {}
    for split, alpha, hetero, cshift in SPLITS:
        clients, test_batch = make_task(3, alpha, seed=11,
                                        hetero_strength=hetero,
                                        concept_shift=cshift)
        for mode in ["fedavg", "ffa", "fedsa"]:
            r = run_fl(mode, "lora", rounds=rounds, clients=clients,
                       test_batch=test_batch)
            out[(split, mode)] = r["best_acc"]
            emit(f"table3/{split}/{mode}", r["s_per_round"] * 1e6,
                 f"acc={r['best_acc']:.4f}")
    return out


if __name__ == "__main__":
    main()
