"""Exporters: Prometheus text exposition, JSON snapshots, sanitize.

``to_prometheus`` renders a ``MetricsRegistry`` in the Prometheus text
exposition format (version 0.0.4): counters and gauges as single
samples, histograms as cumulative ``_bucket{le=...}`` series plus
``_sum``/``_count``. ``validate_exposition`` is the matching checker
used by tests and the CI ``metrics-smoke`` step.

``sanitize`` is the one NaN policy for every serialized report:
non-finite floats become ``None`` (→ JSON ``null``) recursively, so
``json.dumps(..., allow_nan=False)`` never emits the non-standard
``NaN``/``Infinity`` tokens that strict parsers reject. BENCH records,
``--out`` files, and metric snapshots all route through it.

CLI (the CI validation entry point):

  PYTHONPATH=src python -m repro.obs.export \\
      --check-metrics metrics.prom --check-trace trace.jsonl
"""
from __future__ import annotations

import json
import math
import pathlib
import re

from repro.obs.metrics import Counter, Gauge, Histogram

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+(\S+)$")


def sanitize(obj):
    """Recursively map non-finite floats to None (JSON ``null``)."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize(v) for v in obj]
    return obj


def _fmt(v):
    """Prometheus sample value: non-finite renders as +Inf/-Inf/NaN
    (legal in the exposition format, unlike in JSON)."""
    if isinstance(v, float) and not math.isfinite(v):
        if math.isnan(v):
            return "NaN"
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


def to_prometheus(registry):
    """Text exposition of every metric in the registry."""
    lines = []
    for m in registry:
        assert _NAME_RE.match(m.name), f"bad metric name {m.name!r}"
        if m.help:
            lines.append(f"# HELP {m.name} {m.help}")
        if isinstance(m, Counter):
            lines.append(f"# TYPE {m.name} counter")
            lines.append(f"{m.name} {m.value}")
        elif isinstance(m, Gauge):
            lines.append(f"# TYPE {m.name} gauge")
            lines.append(f"{m.name} {_fmt(m.value)}")
        elif isinstance(m, Histogram):
            lines.append(f"# TYPE {m.name} histogram")
            cum = 0
            for bound, c in zip(m.bounds, m.counts):
                cum += c
                lines.append(f'{m.name}_bucket{{le="{_fmt(bound)}"}} {cum}')
            lines.append(f'{m.name}_bucket{{le="+Inf"}} {m.count}')
            lines.append(f"{m.name}_sum {_fmt(m.sum)}")
            lines.append(f"{m.name}_count {m.count}")
    return "\n".join(lines) + "\n"


def to_json(registry):
    """Sanitized JSON-able snapshot of the registry."""
    return sanitize(registry.snapshot())


def write_metrics(path, registry):
    """Write the registry to ``path`` — Prometheus text for ``.prom`` /
    ``.txt`` / ``.metrics``, JSON snapshot otherwise."""
    path = pathlib.Path(path)
    if path.suffix in (".prom", ".txt", ".metrics"):
        path.write_text(to_prometheus(registry))
    else:
        path.write_text(json.dumps(to_json(registry), indent=2,
                                   allow_nan=False) + "\n")
    return path


def validate_exposition(text):
    """Validate Prometheus text exposition content.

    Returns ``(n_samples, errors)``. Checks: every non-comment line is
    ``name[{labels}] value``; every sample's base name was declared by
    a ``# TYPE`` line; histogram ``_bucket`` series are cumulative and
    end with ``le="+Inf"`` matching ``_count``.
    """
    errors = []
    types = {}
    samples = []
    for i, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in ("counter", "gauge",
                                                   "histogram"):
                errors.append(f"line {i}: malformed TYPE line")
            else:
                types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            errors.append(f"line {i}: unparseable sample {line!r}")
            continue
        name, labels, value = m.groups()
        try:
            v = float(value.replace("+Inf", "inf").replace("-Inf", "-inf"))
        except ValueError:
            errors.append(f"line {i}: bad sample value {value!r}")
            continue
        samples.append((name, labels, v))
        base = re.sub(r"_(bucket|sum|count)$", "", name)
        if name not in types and base not in types:
            errors.append(f"line {i}: sample {name!r} has no TYPE line")

    # histogram structure: cumulative buckets, +Inf bucket == _count
    for base, kind in types.items():
        if kind != "histogram":
            continue
        buckets = [(labels, v) for name, labels, v in samples
                   if name == base + "_bucket"]
        counts = [v for name, _, v in samples if name == base + "_count"]
        if not buckets or not counts:
            errors.append(f"histogram {base}: missing _bucket or _count")
            continue
        last = -1.0
        for labels, v in buckets:
            if v < last:
                errors.append(f"histogram {base}: non-cumulative buckets")
                break
            last = v
        if 'le="+Inf"' not in (buckets[-1][0] or ""):
            errors.append(f"histogram {base}: last bucket is not +Inf")
        elif buckets[-1][1] != counts[0]:
            errors.append(f"histogram {base}: +Inf bucket != _count")
    return len(samples), errors


def main(argv=None):
    import argparse

    from repro.obs.trace import validate_trace

    ap = argparse.ArgumentParser(
        description="validate obs artifacts (CI metrics-smoke)")
    ap.add_argument("--check-metrics", default=None,
                    help="Prometheus text exposition file to validate")
    ap.add_argument("--check-trace", default=None,
                    help="JSONL event-trace file to validate")
    ap.add_argument("--min-events", type=int, default=1,
                    help="fail when the trace has fewer events")
    ap.add_argument("--require-events", default="",
                    help="comma-separated event types that must appear")
    args = ap.parse_args(argv)
    failed = False
    if args.check_metrics:
        text = pathlib.Path(args.check_metrics).read_text()
        n, errors = validate_exposition(text)
        for e in errors:
            print(f"[metrics] {e}")
        failed |= bool(errors) or n == 0
        print(f"[metrics] {args.check_metrics}: {n} samples, "
              f"{len(errors)} errors")
    if args.check_trace:
        text = pathlib.Path(args.check_trace).read_text()
        n, errors = validate_trace(text)
        for e in errors:
            print(f"[trace] {e}")
        failed |= bool(errors) or n < args.min_events
        seen = set()
        for line in text.splitlines():
            if line.strip():
                try:
                    seen.add(json.loads(line).get("ev"))
                except ValueError:
                    pass
        want = [e for e in args.require_events.split(",") if e]
        missing = [e for e in want if e not in seen]
        if missing:
            print(f"[trace] missing required event types: {missing}")
            failed = True
        print(f"[trace] {args.check_trace}: {n} events "
              f"({len(seen)} types), {len(errors)} errors")
    return 1 if failed else 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
