"""repro.obs: metrics, latency tracing, and a structured event timeline.

The shared observability vocabulary for the serving + federation stack
(paxml's ``base_metrics``/``summary_utils`` split is the exemplar):

  ``metrics``   Counter / Gauge / Histogram (fixed log-spaced buckets
                with p50/p90/p99 estimation) in a named
                ``MetricsRegistry``, plus the ``Timer`` context manager
  ``trace``     ``TraceLog``: append-only timeline of typed events
                (admit, prefill_batch, decode_scan, flip, …) with
                monotonic timestamps and engine tick ids, serialized as
                JSONL
  ``export``    Prometheus text exposition + JSON snapshot writers and
                the ``sanitize`` helper (non-finite floats → ``null``
                so serialized reports stay strict-parser-valid)
  ``profiler``  ``jax.profiler`` ``TraceAnnotation`` / ``named_scope``
                wrappers so device profiles line up with host events

``ServingEngine`` owns a ``MetricsRegistry`` by default (TTFT /
inter-token / e2e / queue-wait histograms behind ``report()``'s
percentiles) and emits timeline events when constructed with a
``TraceLog``; ``core.federation.run_rounds(metrics=...)`` reports
per-round train metrics through the same registry. See
``docs/observability.md`` for the metric catalog and event schema.
"""
from repro.obs.export import (sanitize, to_json, to_prometheus,
                              validate_exposition, write_metrics)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               Timer)
from repro.obs.profiler import annotate, named_scope
from repro.obs.trace import EVENT_SCHEMA, TraceLog, validate_trace

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "Timer",
           "TraceLog", "EVENT_SCHEMA", "validate_trace", "sanitize",
           "to_json", "to_prometheus", "validate_exposition",
           "write_metrics", "annotate", "named_scope"]
