"""``jax.profiler`` integration: line device profiles up with host events.

Two wrappers, both graceful no-ops when jax (or the profiler API) is
unavailable, so obs consumers never gate on the accelerator toolchain:

  ``annotate(name)``      host-side ``jax.profiler.TraceAnnotation`` —
                          wraps the *dispatch* of a jitted step, so the
                          engine's prefill/decode/fused-scan calls show
                          up as named spans in a ``jax.profiler``
                          capture, alignable with the ``TraceLog``
                          timeline by wall order
  ``named_scope(name)``   ``jax.named_scope`` — names the HLO ops
                          *inside* a traced function, so the device
                          timeline attributes kernels back to the
                          serving phase that launched them

``start_trace``/``stop_trace`` proxy ``jax.profiler`` captures (used
ad hoc when profiling a serving run; nothing in the repo calls them on
the hot path).
"""
from __future__ import annotations

import contextlib

try:
    import jax
    _TRACE_ANNOTATION = getattr(jax.profiler, "TraceAnnotation", None)
    _NAMED_SCOPE = getattr(jax, "named_scope", None)
except ImportError:            # pragma: no cover - jax is baked in here
    jax = None
    _TRACE_ANNOTATION = _NAMED_SCOPE = None


def annotate(name):
    """Host-side profiler span (no-op context without the profiler)."""
    if _TRACE_ANNOTATION is None:
        return contextlib.nullcontext()
    return _TRACE_ANNOTATION(name)


def named_scope(name):
    """Name HLO ops emitted under this scope (no-op without jax)."""
    if _NAMED_SCOPE is None:
        return contextlib.nullcontext()
    return _NAMED_SCOPE(name)


def start_trace(logdir):
    """Begin a ``jax.profiler`` capture; returns True when started."""
    if jax is None or not hasattr(jax.profiler, "start_trace"):
        return False
    jax.profiler.start_trace(str(logdir))
    return True


def stop_trace():
    if jax is not None and hasattr(jax.profiler, "stop_trace"):
        jax.profiler.stop_trace()
