"""Metrics core: Counter / Gauge / Histogram in a named registry.

Design constraints (these run on the serving hot path — once per decode
tick and once per retired request, under a ≤5% overhead budget enforced
by ``tests/test_obs.py``):

  * ``Histogram`` uses FIXED log-spaced buckets chosen at construction
    — ``observe`` is one ``bisect`` plus a handful of float adds, no
    allocation, no rebucketing. Percentiles are estimated by geometric
    interpolation inside the matched bucket, so the worst-case relative
    error is the bucket width ratio (``10 ** (1/per_decade)``, ~1.47×
    at the default 6 buckets/decade) and in practice far less.
  * Counters are **lifetime-monotonic** (Prometheus semantics — a reset
    would break ``rate()``); histograms and gauges are *windowed*:
    ``MetricsRegistry.reset_window()`` zeroes them so a report's
    percentiles cover exactly the timed pass (e.g. after a benchmark
    warm-up), while counters keep counting across windows.

``MetricsRegistry.{counter,gauge,histogram}`` are get-or-create: two
subsystems naming the same metric share one instance, which is how the
engine and the registry (publish→flip latency) and ``run_rounds``
(per-round train metrics) all report through a single registry.
"""
from __future__ import annotations

import math
import time
from bisect import bisect_left

_INF = float("inf")


class Counter:
    """Monotonically increasing count. Never reset (see module doc)."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name, help=""):
        self.name, self.help = name, help
        self.value = 0

    def inc(self, n=1):
        assert n >= 0, f"counter {self.name} cannot decrease"
        self.value += n


class Gauge:
    """Point-in-time value (occupancy, loss, pool fill)."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name, help=""):
        self.name, self.help = name, help
        self.value = 0.0

    def set(self, v):
        self.value = float(v)

    def reset(self):
        self.value = 0.0


class Histogram:
    """Fixed log-spaced buckets with percentile estimation.

    Bucket ``i`` counts observations in ``(bounds[i-1], bounds[i]]``;
    values ≤ ``lo`` land in bucket 0 and values > ``hi`` in the +Inf
    overflow bucket. ``observe(v, n)`` books ``n`` identical
    observations in one call (the fused decode path times a T-token
    block with one host sync, so per-token gaps arrive in blocks).
    """

    __slots__ = ("name", "help", "bounds", "counts", "count", "sum",
                 "min", "max")

    def __init__(self, name, help="", *, lo=1e-5, hi=1e2, per_decade=6):
        assert lo > 0 and hi > lo
        self.name, self.help = name, help
        n = int(math.ceil(per_decade * math.log10(hi / lo)))
        self.bounds = [lo * 10.0 ** (i / per_decade) for i in range(n + 1)]
        self.reset()

    def reset(self):
        self.counts = [0] * (len(self.bounds) + 1)   # +1: +Inf overflow
        self.count = 0
        self.sum = 0.0
        self.min = _INF
        self.max = -_INF

    def observe(self, v, n=1):
        v = float(v)
        self.counts[bisect_left(self.bounds, v)] += n
        self.count += n
        self.sum += v * n
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self):
        return self.sum / self.count if self.count else None

    def percentile(self, q):
        """Estimated q-th percentile (q in [0, 100]); None when empty.

        Finds the bucket holding the nearest-rank target and
        interpolates geometrically inside it (log-spaced buckets make
        the geometric midpoint the unbiased guess), clamped to the
        exact observed [min, max] so single-observation histograms and
        the extreme percentiles stay honest.
        """
        if self.count == 0:
            return None
        target = max(1, int(math.ceil(q / 100.0 * self.count)))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= target:
                lo = self.bounds[i - 1] if i > 0 else self.min
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                lo = min(max(lo, self.min), self.max)
                hi = min(max(hi, self.min), self.max)
                if lo <= 0 or hi <= 0:        # degenerate (≤0 observed)
                    return lo
                frac = (target - (cum - c)) / c
                return lo * (hi / lo) ** frac
        return self.max                        # unreachable

    def snapshot(self):
        """JSON-able summary (non-finite → None happens in export)."""
        return {"count": self.count, "sum": self.sum,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "mean": self.mean,
                "p50": self.percentile(50),
                "p90": self.percentile(90),
                "p99": self.percentile(99)}


class MetricsRegistry:
    """Named collection of metrics; the unit every subsystem reports to.

    Instantiate one per engine / experiment and pass it around —
    ``counter``/``gauge``/``histogram`` return the existing instance
    when the name is already registered (a name may not change kind).
    """

    def __init__(self, namespace="repro"):
        self.namespace = namespace
        self._metrics = {}                 # name → metric (ordered)

    def _get(self, cls, name, help, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, **kw)
        elif type(m) is not cls:
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"not {cls.__name__}")
        return m

    def counter(self, name, help=""):
        return self._get(Counter, name, help)

    def gauge(self, name, help=""):
        return self._get(Gauge, name, help)

    def histogram(self, name, help="", **kw):
        return self._get(Histogram, name, help, **kw)

    def timer(self, name, help=""):
        """Timer recording into the named histogram."""
        return Timer(self.histogram(name, help))

    def __iter__(self):
        return iter(self._metrics.values())

    def __contains__(self, name):
        return name in self._metrics

    def reset_window(self):
        """Zero histograms and gauges (e.g. after a warm-up pass);
        counters stay monotonic across windows."""
        for m in self:
            if isinstance(m, (Histogram, Gauge)):
                m.reset()

    def snapshot(self):
        """Nested JSON-able dict of every metric's current state."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for m in self:
            if isinstance(m, Counter):
                out["counters"][m.name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][m.name] = m.value
            else:
                out["histograms"][m.name] = m.snapshot()
        return out


class Timer:
    """``perf_counter`` span as a context manager.

    ``with Timer(hist):`` records the elapsed seconds into ``hist`` on
    exit; ``Timer()`` just measures (``.elapsed`` after the block —
    the shared replacement for ad-hoc ``time.time()`` deltas in the
    launchers). Re-enterable: each ``with`` records one span.
    """

    __slots__ = ("hist", "elapsed", "_t0")

    def __init__(self, hist=None):
        self.hist = hist
        self.elapsed = 0.0
        self._t0 = None

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self._t0
        if self.hist is not None:
            self.hist.observe(self.elapsed)
        return False
