"""TraceLog: append-only structured event timeline, serialized as JSONL.

Every event is one flat dict:

  {"ev": <type>, "ts": <seconds since log start, monotonic>,
   "tick": <engine tick id or null>, ...type-specific fields}

``EVENT_SCHEMA`` names the required fields per type — the contract the
CI ``metrics-smoke`` step and ``tests/test_obs.py`` validate against.
Emitters attach extra fields freely (the schema is a floor, not a
ceiling), so e.g. ``retire`` carries the adapter version alongside its
required latency fields.

The log is bounded (``maxlen``, default 2^17 events): once full, new
events are dropped and counted in ``.dropped`` rather than growing the
host heap under a long-lived engine — the timeline is a flight
recorder, not a durable audit log. ``current_tick`` is stamped by the
engine at the top of each ``step()`` so events emitted from the
scheduler and registry (which don't know about ticks) still line up
with the engine timeline.
"""
from __future__ import annotations

import json
import threading
import time

# event type → required fields (beyond ev/ts/tick). Keep in sync with
# docs/observability.md.
EVENT_SCHEMA = {
    "submit": ("rid", "client"),
    "admit": ("rid", "client", "row", "slot", "queue_wait_s"),
    "prefill_batch": ("bucket", "rows", "wall_s"),
    "decode_scan": ("ticks", "rows", "wall_s"),
    "flip": ("version",),
    "deferred_flip": ("version", "blocking_rows"),
    "eviction": ("client", "slot"),
    "pool_exhausted": ("client", "needed", "free"),
    "tick_shrink": ("from_ticks", "to_ticks"),
    "retire": ("rid", "client", "tokens", "queue_wait_s", "ttft_s",
               "e2e_s"),
    # robustness vocabulary (PR 7 — see docs/robustness.md)
    "fault_injected": ("kind",),
    "client_dropped": ("round", "client", "reason"),
    "update_rejected": ("round", "client", "reason"),
    "request_shed": ("client", "reason"),
    "deadline_exceeded": ("rid", "client"),
    "degraded_serve": ("rid", "client", "reason"),
    "rollback": ("reason",),
    # adapter tiering vocabulary (PR 8 — see docs/serving.md)
    "adapter_prefetch": ("client",),
    "tier_miss": ("client", "tier"),
    "tier_prestage": ("client", "slot"),
    # prefix-cache vocabulary (PR 10 — see docs/serving.md §7)
    "prefix_hit": ("rid", "client", "tokens", "pages"),
    "cow_copy": ("row", "page"),
    "prefix_evict": ("pages",),
}


class TraceLog:
    """Bounded append-only event timeline with monotonic timestamps."""

    def __init__(self, maxlen=1 << 17, *, validate=False):
        self.events = []
        self.maxlen = maxlen
        self.dropped = 0
        self.validate = validate
        self.current_tick = None
        self._t0 = time.perf_counter()
        # emitters may live on several threads (train_and_serve runs the
        # federation loop beside the engine): stamp-and-append under a
        # lock so timestamps stay nondecreasing in event order
        self._lock = threading.Lock()

    def emit(self, ev, *, tick=None, **fields):
        """Append one typed event; unknown types raise (the schema is
        the vocabulary downstream tooling understands)."""
        required = EVENT_SCHEMA.get(ev)
        if required is None:
            raise KeyError(f"unknown trace event type {ev!r}")
        if self.validate:
            missing = [f for f in required if f not in fields]
            if missing:
                raise ValueError(f"{ev} event missing {missing}")
        with self._lock:
            if len(self.events) >= self.maxlen:
                self.dropped += 1
                return
            rec = {"ev": ev, "ts": time.perf_counter() - self._t0,
                   "tick": self.current_tick if tick is None else tick}
            rec.update(fields)
            self.events.append(rec)

    def __len__(self):
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def by_type(self, ev):
        return [e for e in self.events if e["ev"] == ev]

    def to_jsonl(self):
        return "".join(json.dumps(e, allow_nan=False) + "\n"
                       for e in self.events)

    def save(self, path):
        """Write the timeline as JSONL (one event per line)."""
        with open(path, "w") as f:
            f.write(self.to_jsonl())
        return path


def validate_trace(lines):
    """Validate JSONL trace content (an iterable of lines or one str).

    Returns ``(n_events, errors)`` — every line must parse as strict
    JSON (no NaN/Infinity), carry a known ``ev`` with its required
    fields plus ``ts``/``tick``, and timestamps must be nondecreasing.
    """
    if isinstance(lines, str):
        lines = lines.splitlines()

    def reject_constant(c):
        raise ValueError(f"non-standard JSON constant {c}")

    errors = []
    last_ts = -1.0
    n = 0
    for i, line in enumerate(lines, 1):
        line = line.strip()
        if not line:
            continue
        n += 1
        try:
            e = json.loads(line, parse_constant=reject_constant)
        except ValueError as err:
            errors.append(f"line {i}: {err}")
            continue
        ev = e.get("ev")
        required = EVENT_SCHEMA.get(ev)
        if required is None:
            errors.append(f"line {i}: unknown event type {ev!r}")
            continue
        missing = [f for f in ("ts", "tick") + required if f not in e]
        if missing:
            errors.append(f"line {i}: {ev} missing {missing}")
        ts = e.get("ts")
        if isinstance(ts, (int, float)):
            if ts < last_ts:
                errors.append(f"line {i}: ts went backwards "
                              f"({ts} < {last_ts})")
            last_ts = ts
    return n, errors
