from repro.sharding.rules import (adapter_specs, batch_specs, cache_specs,
                                  param_specs)

__all__ = ["adapter_specs", "batch_specs", "cache_specs", "param_specs"]
