"""PartitionSpec rules for the production mesh (DESIGN.md §3.4).

Mesh axes: single-pod ``("data", "model")`` = (16, 16); multi-pod
``("pod", "data", "model")`` = (2, 16, 16). ``dp`` below means the composite
data axis — ``("pod", "data")`` when a pod axis exists, else ``"data"``.

Strategy
--------
* Megatron tensor parallelism over ``"model"`` for every projection
  (column-parallel into attention/MLP, row-parallel out), vocab-parallel
  embeddings, expert-parallel MoE when E divides the model axis.
* Clients ARE the dp axis: adapter trees (and optimizer state) carry a
  leading client axis sharded over dp. The selective aggregation mean then
  lowers to an all-reduce over dp of the *shared* leaves only.
* Frozen base weights whose per-model-shard footprint is large are
  additionally ZeRO-sharded over dp on the non-model dimension (they are
  all-gathered on use; frozen weights have no optimizer state or gradient,
  so this is pure memory relief).
* Caches: batch over dp; KV heads over ``"model"`` when divisible, else
  sequence over ``"model"`` (flash-decode: GSPMD turns the masked softmax
  reductions into small all-reduces). SSM state: d_inner/heads over
  ``"model"``.

Only *boundary* tensors (params, adapters, optimizer state, inputs, caches)
are constrained; interior activations are left to GSPMD propagation.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

# modules whose OUTPUT feature dim is model-sharded (column-parallel)
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "dt_proj",
        "wq_b", "wkv_b", "wq_a"}
# modules whose INPUT feature dim is model-sharded (row-parallel)
_ROW = {"wo", "w_down", "out_proj", "x_proj", "proj"}
# small / deliberately replicated
_REPL = {"wkv_a", "router"}

_NORM_HINTS = ("ln", "norm", "dt_bias", "gamma", "beta", "b")


def dp_axis(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _model_size(mesh):
    return mesh.shape["model"]


def _dp_size(mesh):
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names
                        if a != "model"]))


def _names(path):
    return [str(p.key) for p in path if hasattr(p, "key")]


def _pad(ndim, trail):
    """Left-pad a trailing spec with None up to ndim axes."""
    trail = tuple(trail)
    assert len(trail) <= ndim, (ndim, trail)
    return P(*((None,) * (ndim - len(trail)) + trail))


# ---------------------------------------------------------------------------
# Base model params
# ---------------------------------------------------------------------------

def _param_trail(names, leaf, mesh, zero3_bytes):
    """Trailing-dim spec for one base-param leaf."""
    name = names[-1]
    dp = dp_axis(mesh)
    msize = _model_size(mesh)
    big = leaf.size * 2 >= zero3_bytes          # bf16 footprint heuristic

    if name == "embed":
        return ("model", None)
    if name == "head":
        return (None, "model")
    # MoE expert stacks: (E, d_in, d_out) under a "moe" subtree.
    # Expert-parallel over "model"; for memory relief the expert HIDDEN
    # dim f is additionally dp-sharded when big (Megatron col→row WITHIN
    # the expert: gate/up outputs and the down contraction align on f, so
    # only one partial-sum all-reduce per block remains — §Perf it. 2b).
    # (Tried and REFUTED, §Perf it. 2a: E over ("model","data") jointly —
    # GSPMD cannot reshard a data-dependent scatter destination and
    # replicates the dispatch buffer: collective term 243s → 1760s. Joint
    # expert-parallel needs explicit shard_map all-to-all. Also refuted:
    # ZeRO-sharding the CONTRACTION dims over dp — every expert matmul
    # partial-summed over dp.)
    if "moe" in names and "shared" not in names and name in (
            "w_gate", "w_up", "w_down"):
        E = leaf.shape[-3]
        if E % msize == 0:
            # baseline layout: E expert-parallel over "model", ZeRO over dp
            # on the input dim. (it. 2b — f-over-dp to align gate/up/down —
            # measured WORSE: 243s → 290s collective; GSPMD resolved the
            # h-tensor conflict with extra gathers. Kept: d-over-dp.)
            return ("model", dp if big else None, None)
        # granite: E=40 not divisible — shard the expert hidden dim
        if name == "w_down":
            return (None, "model", None)
        return (None, None, "model")
    if name in ("conv_w",):
        return (None, "model")
    if name == "A_log":
        return ("model", None) if leaf.shape[-1] > 1 and leaf.ndim >= 2 \
            and names[-2] == "mixer" and leaf.shape[-1] != leaf.shape[-2] \
            else ("model",)
    if name in ("conv_b", "D", "dt_bias"):
        return ("model",)
    if name in _REPL:
        return (None, None)
    if name in _COL:
        extra = dp if big else None
        return (extra, "model")
    if name in _ROW:
        extra = dp if big else None
        return ("model", extra)
    # norms / biases / scalars → replicated
    return ()


def param_specs(cfg, params_shape, mesh, *, zero3_bytes=2 ** 32):
    """PartitionSpec pytree for ``init_model``-shaped params.

    ``params_shape``: pytree of ShapeDtypeStructs (from ``jax.eval_shape``)
    or concrete arrays. ``zero3_bytes``: leaves whose total bf16 footprint
    exceeds this are additionally dp-sharded.
    """
    def rule(path, leaf):
        names = _names(path)
        # A_log disambiguation is fragile via shapes; redo cleanly here
        if names[-1] == "A_log":
            trail = ("model", None) if (leaf.ndim - _n_stack(path)) == 2 \
                else ("model",)
        else:
            trail = _param_trail(names, leaf, mesh, zero3_bytes)
        return _pad(leaf.ndim, trail)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def _n_stack(path):
    """Number of leading stacked-layer axes implied by the path (segments
    carry one scan axis; hybrid mamba carries two)."""
    names = _names(path)
    n = 0
    if "segments" in names:
        n = 1
        if "mamba" in names:
            n = 2
    return n


# ---------------------------------------------------------------------------
# Adapters (and optimizer state, which mirrors them)
# ---------------------------------------------------------------------------

def _adapter_trail(names, mesh):
    name = names[-1]
    if "vera_shared" in names:
        return (None, None)
    # find the adapted module name (…/<module>/<leaf> or …/<module>/global/<leaf>)
    module = None
    for cand in reversed(names[:-1]):
        if cand not in ("global", "personal"):
            module = cand
            break
    col = module in _COL
    if name == "A":
        return (None, None)
    if name == "B":
        return (None, "model") if col else (None, None)
    if name == "d":
        return (None,)
    if name == "b":
        return ("model",) if col else (None,)
    if name == "w":                             # cls head
        return (None, None)
    return ()


def adapter_specs(cfg, adapters_shape, mesh, *, client_axis=False):
    """Specs for an adapter tree; ``client_axis=True`` shards a leading
    client dimension over dp (the in-mesh federated layout)."""
    dp = dp_axis(mesh)

    def rule(path, leaf):
        names = _names(path)
        trail = _adapter_trail(names, mesh)
        lead = (dp,) if client_axis else ()
        body_ndim = leaf.ndim - len(lead)
        assert body_ndim >= len(trail), (names, leaf.shape)
        return P(*(lead + (None,) * (body_ndim - len(trail)) + trail))

    return jax.tree_util.tree_map_with_path(rule, adapters_shape)


def make_opt_specs(opt_state_shape, trainable_specs_by_shape):
    """Spec tree for optimizer state: every leaf inherits the spec of the
    trainable leaf with the same shape; unknown scalars are replicated."""
    def rule(path, leaf):
        names = _names(path)
        if names and names[-1] == "t":
            return P()
        spec = trainable_specs_by_shape.get(leaf.shape)
        return spec if spec is not None else P()
    return jax.tree_util.tree_map_with_path(rule, opt_state_shape)


def specs_by_shape(tree_shape, tree_specs):
    out = {}
    for leaf, spec in zip(jax.tree_util.tree_leaves(tree_shape),
                          jax.tree_util.tree_leaves(tree_specs,
                                                    is_leaf=lambda x:
                                                    isinstance(x, P))):
        out[leaf.shape] = spec
    return out


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def cache_specs(cfg, cache_shape, mesh, *, batch_over_dp=True):
    """Specs for an ``init_cache`` pytree (with leading layer-scan axis)."""
    dp = dp_axis(mesh) if batch_over_dp else None
    msize = _model_size(mesh)

    def rule(path, leaf):
        names = _names(path)
        name = names[-1]
        if name in ("k", "v", "cross_k", "cross_v"):
            # (n?, B, S, Hkv, hd)
            Hkv = leaf.shape[-2]
            if Hkv % msize == 0:
                trail = (dp, None, "model", None)
            else:
                trail = (dp, "model", None, None)
            return _pad(leaf.ndim, trail)
        if name in ("ckv", "krope"):            # (n?, B, S, r)
            return _pad(leaf.ndim, (dp, "model", None))
        if name == "h":
            if leaf.ndim - _n_stack(path) == 5 or leaf.ndim >= 5:
                # mamba2: (n?, B, nh, hd, ds)
                return _pad(leaf.ndim, (dp, "model", None, None))
            # mamba1: (n?, B, di, ds)
            return _pad(leaf.ndim, (dp, "model", None))
        if name == "conv":                      # (n?, B, k-1, C)
            return _pad(leaf.ndim, (dp, None, "model"))
        return _pad(leaf.ndim, ())

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


# ---------------------------------------------------------------------------
# Serving (repro.serving.sharded)
# ---------------------------------------------------------------------------

def paged_cache_specs(cfg, cache_shape, mesh):
    """Specs for an ``init_paged_cache`` pytree.

    Pool leaves are ``(n, n_pages, page_size, Hkv, hd)``: the PAGE axis
    shards over dp (the pool is the serving batch's K/V, and pages are
    block-partitioned so a row's reservation lands on its row shard —
    see ``PagePool(n_shards=...)``), KV heads over ``"model"`` when
    divisible (the same head split as the dense ``cache_specs`` rule).
    Non-divisible dims fall back to replicated, leaf by leaf.
    """
    dp = dp_axis(mesh)
    dsize = _dp_size(mesh)
    msize = _model_size(mesh)

    def rule(path, leaf):
        names = _names(path)
        if names[-1] in ("k", "v", "cross_k", "cross_v"):
            n_pages, Hkv = leaf.shape[-4], leaf.shape[-2]
            trail = (dp if n_pages % dsize == 0 else None, None,
                     "model" if Hkv % msize == 0 else None, None)
            return _pad(leaf.ndim, trail)
        return _pad(leaf.ndim, ())

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def serving_table_specs(tables, local_tree, mesh):
    """Specs for an ``AdapterRegistry.tables`` tree on a serving mesh.

    Slot tables REPLICATE over dp — any decode row may gather any slot
    id, so splitting the slot axis would turn every gather into an
    all-gather (and the ``n_buffers * (n_slots + 1)`` stride axis is
    rarely divisible anyway). They tensor-shard with the base weights
    instead: a LOCAL table's last (output-feature) dim goes over
    ``"model"`` when divisible — the ``adapter_specs`` B rule, applied
    post-packing — and everything else (A tables with their tiny rank
    dim, shared Ā leaves, norms) stays replicated.
    """
    msize = _model_size(mesh)

    def rule(path, leaf, loc):
        names = _names(path)
        if loc and names and names[-1] == "B":
            trail = _adapter_trail(names, mesh)      # (None, "model") when
            if (trail == (None, "model")             # the module is col-par
                    and leaf.shape[-1] % msize == 0):
                return _pad(leaf.ndim, trail)
        return _pad(leaf.ndim, ())

    return jax.tree_util.tree_map_with_path(rule, tables, local_tree)


# ---------------------------------------------------------------------------
# Batches
# ---------------------------------------------------------------------------

def batch_specs(batch_shape, mesh, *, lead_axis=True):
    """Inputs: leading (client or batch) axis over dp when divisible."""
    dp = dp_axis(mesh)
    dsize = _dp_size(mesh)

    def rule(path, leaf):
        if not lead_axis or leaf.ndim == 0 or leaf.shape[0] % dsize != 0:
            return _pad(leaf.ndim, ())
        return P(*((dp,) + (None,) * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch_shape)
