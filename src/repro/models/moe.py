"""Mixture-of-Experts with capacity-based scatter dispatch.

GShard-style grouped dispatch adapted to avoid the classic dispatch-einsum
FLOP explosion: token→slot routing is computed with one-hot cumsums *per
group* (group = one sequence, so the cumsum axis is never sharded), tokens
are placed into an ``(E, capacity, d)`` buffer with scatter-add (data
movement, no matmul FLOPs), experts run as one grouped einsum, and results
are gathered back and combined with the router weights. ``cost_analysis``
FLOPs therefore stay ≈ active-expert FLOPs.

DeepSeek-V3 extras: ``n_shared_experts`` always-on experts and sigmoid
routing with top-k renormalization.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.mlp import init_mlp, mlp_forward


def init_moe(key, cfg, dtype):
    m = cfg.moe
    d, E, f = cfg.d_model, m.n_experts, m.d_ff
    ks = jax.random.split(key, 5)

    def expert_stack(k, d_in, d_out):
        return (jax.random.normal(k, (E, d_in, d_out), jnp.float32)
                * d_in ** -0.5).astype(dtype)

    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w_gate": expert_stack(ks[1], d, f),
        "w_up": expert_stack(ks[2], d, f),
        "w_down": expert_stack(ks[3], f, d),
    }
    if m.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, dtype,
                               d_ff=f * m.n_shared_experts)
    return p


def _model_axis_size():
    """Mesh "model" axis size when under a mesh context, else 0."""
    try:
        from jax._src import mesh as mesh_lib
        env_mesh = mesh_lib.thread_resources.env.physical_mesh
        if env_mesh.empty or "model" not in env_mesh.axis_names:
            return 0
        return env_mesh.shape["model"]
    except Exception:  # noqa: BLE001
        return 0


# (§Perf it. 2d, REFUTED: with_sharding_constraint(buf, replicated) under
# the client vmap replicated the CLIENT axis too — all-gather 57 TB. wsc
# inside vmap cannot express "replicated over model, sharded over dp".)


def _shard_map_model(fn, mesh, in_specs, out_specs):
    """jax version compat: ``jax.shard_map`` (new spelling, manual over
    "model" only) vs ``jax.experimental.shard_map`` (0.4.x, ``auto=`` set
    for the axes left automatic, ``check_rep`` for ``check_vma``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, axis_names={"model"},
                             in_specs=in_specs, out_specs=out_specs,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map
    # 0.4.x partial-auto lowering emits PartitionId, unsupported by the
    # XLA-CPU SPMD partitioner — run fully manual instead: ``fn`` only
    # uses "model" collectives, and the specs replicate the other axes.
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def _moe_expert_parallel(cfg, p, x, probs_k, ids, capacity):
    """Explicit expert-parallel dispatch via shard_map (§Perf it. 2f).

    GSPMD's handling of the capacity scatter/gather against an E-sharded
    buffer replicates token tensors across the model axis (~9 TB/device
    for deepseek-v3 train). Under ``jax.shard_map`` (manual over "model"
    ONLY — dp stays automatic) each model shard:

      * recomputes the (cheap, replicated) routing bookkeeping,
      * scatters tokens into ITS OWN E/ms experts' buffer — zero comm,
      * runs its expert matmuls locally,
      * emits a partial combine, reduced with ONE psum over "model".

    Cross-model traffic per layer = one (tokens, d) f32 psum — the
    TPU-native analogue of the all-to-all EP schedule (DESIGN.md §3.2).
    """
    from jax._src import mesh as mesh_lib
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    E, k = m.n_experts, m.top_k
    mesh = mesh_lib.thread_resources.env.physical_mesh
    ms = mesh.shape["model"]
    E_local = E // ms
    B, S, d = x.shape

    x_dtype = x.dtype

    def fn(wg, wu, wd, xs, pks, idss):
        xs = xs.astype(x_dtype)       # boundary stays f32 (XLA-CPU's
        sid = jax.lax.axis_index("model")  # AllReducePromotion CHECK-fails
        base = sid * E_local               # on bf16 shard_map collectives)

        def group(xg, ig):
            t, kk = ig.shape
            flat_ids = ig.reshape(t * kk)
            onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)
            rank = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot,
                           axis=-1)
            keep = rank < capacity
            lid = flat_ids - base
            local = (lid >= 0) & (lid < E_local) & keep
            safe_lid = jnp.where(local, lid, 0)
            safe_rank = jnp.where(local, rank, 0)
            xk = jnp.repeat(xg, kk, axis=0) * local[:, None].astype(xg.dtype)
            buf = jnp.zeros((E_local, capacity, xg.shape[-1]), xg.dtype)
            buf = buf.at[safe_lid, safe_rank].add(xk, mode="drop")
            return buf, safe_lid, safe_rank, local

        buf, slid, srank, local = jax.vmap(group)(xs, idss)
        g = jnp.einsum("becd,edf->becf", buf, wg)
        u = jnp.einsum("becd,edf->becf", buf, wu)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xs.dtype) * u
        out = jnp.einsum("becf,efd->becd", h, wd)

        def combine(out_b, sl, sr, loc, pg):
            flat = out_b[sl, sr] * loc[:, None].astype(out_b.dtype)
            y = flat.reshape(pg.shape[0], pg.shape[1], -1)
            return jnp.sum(y.astype(jnp.float32) * pg[..., None], axis=1)

        y = jax.vmap(combine)(out, slid, srank, local, pks)  # (B, t, d)
        # partial combine per shard; the cross-model reduction happens
        # OUTSIDE shard_map (GSPMD all-reduce) — in-shard_map psum /
        # psum_scatter both trip an XLA-CPU CHECK in AllReducePromotion.
        return y[None]                                      # (1, B, t, d)

    wg = jax.lax.stop_gradient(p["w_gate"])
    wu = jax.lax.stop_gradient(p["w_up"])
    wd = jax.lax.stop_gradient(p["w_down"])
    y_parts = _shard_map_model(
        fn, mesh,
        (P("model"), P("model"), P("model"), P(), P(), P()),
        P("model"),
    )(wg, wu, wd, x.astype(jnp.float32), probs_k, ids)
    return jnp.sum(y_parts, axis=0).reshape(B, S, d)  # AR over model


def _gather_experts(p, xf, ids, probs_k):
    """Per-token expert-weight gather. xf: (t, d); ids/probs_k: (t, k)."""
    wg = jax.lax.stop_gradient(p["w_gate"])[ids]            # (t, k, d, f)
    wu = jax.lax.stop_gradient(p["w_up"])[ids]
    wd = jax.lax.stop_gradient(p["w_down"])[ids]            # (t, k, f, d)
    g = jnp.einsum("td,tkdf->tkf", xf, wg)
    u = jnp.einsum("td,tkdf->tkf", xf, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xf.dtype) * u
    out = jnp.einsum("tkf,tkfd->tkd", h, wd).astype(jnp.float32)
    return jnp.sum(out * probs_k[..., None], axis=1).astype(xf.dtype)


def _dispatch_group(x, probs_k, ids, capacity, n_experts):
    """Route one group. x: (t, d); probs_k/ids: (t, k). Returns
    (buffer (E, cap, d), rank (t, k), keep (t, k))."""
    t, k = ids.shape
    flat_ids = ids.reshape(t * k)
    onehot = jax.nn.one_hot(flat_ids, n_experts, dtype=jnp.int32)
    # exclusive cumsum = how many earlier assignments hit the same expert
    rank = (jnp.cumsum(onehot, axis=0) - onehot)
    rank = jnp.sum(rank * onehot, axis=-1)                 # (t*k,)
    keep = rank < capacity
    safe_rank = jnp.where(keep, rank, 0)
    xk = jnp.repeat(x, k, axis=0)                          # (t*k, d)
    xk = xk * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((n_experts, capacity, x.shape[-1]), x.dtype)
    buf = buf.at[flat_ids, safe_rank].add(xk, mode="drop")
    return buf, rank.reshape(t, k), keep.reshape(t, k)


def _combine_group(out_buf, ids, rank, keep, probs_k):
    """Gather expert outputs back to token order and mix with router probs."""
    t, k = ids.shape
    flat = out_buf[ids.reshape(-1), jnp.where(keep, rank, 0).reshape(-1)]
    flat = flat * (keep.reshape(-1, 1)).astype(flat.dtype)
    y = flat.reshape(t, k, -1).astype(jnp.float32)
    return jnp.sum(y * probs_k[..., None], axis=1)         # (t, d)


def moe_forward(cfg, p, ad, acfg, x, *, vera_shared=None):
    """x: (B, S, d) (decode: S == 1). Returns (y, aux_loss).

    Dispatch groups are per-sequence (the cumsum axis stays unsharded). At
    decode (S == 1) a per-row group would force capacity ≥ 1 slot per
    expert per token — E/top_k× wasted expert FLOPs — so the batch is
    regrouped into ONE dispatch group over all B tokens.
    """
    m = cfg.moe
    B, S, d = x.shape
    if S == 1 and B > 1:
        y, aux = moe_forward(cfg, p, ad, acfg, x.reshape(1, B, d),
                             vera_shared=vera_shared)
        return y.reshape(B, S, d), aux
    E, k = m.n_experts, m.top_k
    capacity = max(1, int(S * k * m.capacity_factor / E))

    logits = (x.astype(jnp.float32) @ p["router"])          # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    probs_k, ids = jax.lax.top_k(probs, k)
    probs_k = probs_k / jnp.sum(probs_k, axis=-1, keepdims=True)

    if B * S <= 8:
        # Tiny token counts (B=1 long-context decode): capacity dispatch
        # would burn E/k× the active FLOPs — gather the k expert matrices
        # per token instead (compute AND bytes then match active experts).
        y = _gather_experts(p, x.reshape(B * S, d),
                            ids.reshape(B * S, k),
                            probs_k.reshape(B * S, k)).reshape(B, S, d)
        if "shared" in p:
            y = y + mlp_forward(cfg, p["shared"], None, acfg, x,
                                vera_shared=vera_shared)
        return y.astype(x.dtype), jnp.zeros((), jnp.float32)

    # load-balance aux loss (Switch-style): E * Σ_e f_e · p̄_e. Occupancy
    # via histogram scatter — the (B, S, k, E) one-hot materialization it
    # replaces cost ~0.5 GB/client/layer in reductions (§Perf it. 2e).
    occupancy = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(
        1.0 / (B * S))                                      # (E,)
    aux = E * jnp.sum(occupancy * jnp.mean(probs, axis=(0, 1)))

    ms = _model_axis_size()
    if ms > 1 and E % ms == 0 and m.expert_parallel:
        # opt-in explicit expert-parallel schedule (it. 2f)
        y = _moe_expert_parallel(cfg, p, x, probs_k, ids, capacity)
        if "shared" in p:
            y = y + mlp_forward(cfg, p["shared"], None, acfg, x,
                                vera_shared=vera_shared).astype(jnp.float32)
        return y.astype(x.dtype), m.router_aux_coef * aux

    buf, rank, keep = jax.vmap(
        lambda xv, pv, iv: _dispatch_group(xv, pv, iv, capacity, E)
    )(x, probs_k, ids)                                      # buf: (B, E, cap, d)

    w_gate = jax.lax.stop_gradient(p["w_gate"])
    w_up = jax.lax.stop_gradient(p["w_up"])
    w_down = jax.lax.stop_gradient(p["w_down"])
    g = jnp.einsum("becd,edf->becf", buf, w_gate)
    u = jnp.einsum("becd,edf->becf", buf, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out_buf = jnp.einsum("becf,efd->becd", h, w_down)       # (B, E, cap, d)

    y = jax.vmap(_combine_group)(out_buf, ids, rank, keep, probs_k)
    y = y.reshape(B, S, d).astype(x.dtype)

    if "shared" in p:
        y = y + mlp_forward(cfg, p["shared"], None, acfg, x,
                            vera_shared=vera_shared)
    return y, m.router_aux_coef * aux
