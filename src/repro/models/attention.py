"""Attention: GQA (+qk_norm, sliding window, cross-attn) and MLA.

Full-sequence attention is blockwise (flash-style online softmax over KV
chunks, scanned over Q chunks) so prefill at 32k never materializes S×S
scores. Decode attends one query against the cache with masked positions;
with a sequence-sharded cache GSPMD lowers the max/sum reductions to small
all-reduces (flash-decode for free).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (adapted, apply_rope, dense_init,
                                 effective_weight, maybe, rms_norm)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype, cross=False):
    d, H, Hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, H * hd, dtype),
        "wk": dense_init(ks[1], d, Hkv * hd, dtype),
        "wv": dense_init(ks[2], d, Hkv * hd, dtype),
        "wo": dense_init(ks[3], H * hd, d, dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _qkv(cfg, p, ad, acfg, x, kv_x, vera_shared):
    """Project to per-head q, k, v (no rope yet)."""
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    sc = acfg.scaling if acfg is not None else 1.0
    vs = (vera_shared or {})
    q = adapted(p["wq"], maybe(ad, "wq"), x, sc, vs.get("wq"))
    k = adapted(p["wk"], maybe(ad, "wk"), kv_x, sc, vs.get("wk"))
    v = adapted(p["wv"], maybe(ad, "wv"), kv_x, sc, vs.get("wv"))
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, kv_x.shape[1], Hkv, hd)
    v = v.reshape(B, kv_x.shape[1], Hkv, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def blockwise_attention(q, k, v, q_pos, k_pos, *, causal, window=None,
                        q_chunk=512, kv_chunk=1024):
    """Online-softmax attention.

    q: (B, S, H, hd); k, v: (B, T, Hkv, hd); *_pos: (S,)/(T,) int32.
    Returns (B, S, H, hd). Exact; memory is O(q_chunk × kv_chunk).
    """
    B, S, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]           # MLA: v head dim may differ from qk head dim
    G = H // Hkv
    qc = min(q_chunk, S)
    kvc = min(kv_chunk, T)
    # pad to multiples
    Sp = -(-S // qc) * qc
    Tp = -(-T // kvc) * kvc
    q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    q_pos = jnp.pad(q_pos, (0, Sp - S))
    k_pos = jnp.pad(k_pos, (0, Tp - T), constant_values=jnp.iinfo(jnp.int32).max)

    q = q.reshape(B, Sp // qc, qc, Hkv, G, hd).transpose(1, 0, 3, 4, 2, 5)
    # (nq, B, Hkv, G, qc, hd)
    kb = k.reshape(B, Tp // kvc, kvc, Hkv, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, Tp // kvc, kvc, Hkv, hdv).transpose(1, 0, 3, 2, 4)
    qp = q_pos.reshape(Sp // qc, qc)
    kp = k_pos.reshape(Tp // kvc, kvc)
    scale = hd ** -0.5

    def q_block(args):
        qi, qpi = args  # (B, Hkv, G, qc, hd), (qc,)

        def kv_block(carry, inp):
            m, l, acc = carry
            ki, vi, kpi = inp
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qi.astype(jnp.float32),
                           ki.astype(jnp.float32)) * scale
            mask = jnp.ones((qc, kvc), bool)
            if causal:
                mask &= qpi[:, None] >= kpi[None, :]
            if window is not None:
                mask &= (qpi[:, None] - kpi[None, :]) < window
            mask &= (kpi < jnp.iinfo(jnp.int32).max)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qc), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qc, hdv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), (kb, vb, kp))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(q_block, (q, qp))           # (nq, B, Hkv, G, qc, hdv)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sp, H, hdv)
    return out[:, :S].astype(v.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window=None):
    """One-token attention against a cache.

    q: (B, 1, H, hd); caches: (B, Smax, Hkv, hd); pos: (B,) current index
    (cache holds valid entries at [0, pos]).
    """
    B, _, H, hd = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * hd ** -0.5
    idx = jnp.arange(Smax)[None, :]                 # (1, Smax)
    valid = idx <= pos[:, None]
    if window is not None:
        valid &= (pos[:, None] - idx) < window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H * hd).astype(v_cache.dtype)


def paged_gather(pages, block_tables):
    """Materialize a logical-order KV view from the page pool.

    pages: (n_pages, page_size, Hkv, hd); block_tables: (B, P) int32
    physical page ids in logical order. Returns (B, P·page_size, Hkv, hd).
    """
    B, P = block_tables.shape
    page = pages.shape[1]
    flat = jnp.take(pages, block_tables.reshape(-1), axis=0)
    return flat.reshape(B, P * page, *pages.shape[2:])


def attn_decode_paged(cfg, p, ad, acfg, x, pos, k_pages, v_pages,
                      block_tables, *, window=None, backend="xla",
                      vera_shared=None):
    """One-step decode against a paged KV cache.

    x: (B, 1, d); pos: (B,); k_pages/v_pages: (n_pages, page, Hkv, hd);
    block_tables: (B, P) physical page ids (page 0 of the pool is the
    write-off page shared by retired/padded rows).

    The pools are READ-ONLY here: threading per-layer pool updates
    through the layer scan makes XLA rebuild every page each step, which
    costs exactly the dense-layout traffic paging is meant to avoid.
    Instead the xla backend inserts the new K/V row into the *gathered*
    logical view (numerically identical — pages are disjoint) and the
    caller commits all layers' rows with ONE post-scan scatter into the
    (donated) pool. The pallas backend passes the row to the kernel,
    which appends it to the VMEM-resident page block before attending
    (in-kernel append — no per-layer pool copy).

    Returns (y, k_row (B, Hkv, hd), v_row (B, Hkv, hd)).
    """
    B = x.shape[0]
    q, k, v = _qkv(cfg, p, ad, acfg, x, x, vera_shared)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    k_row = k[:, 0].astype(k_pages.dtype)
    v_row = v[:, 0].astype(v_pages.dtype)
    if backend == "pallas":
        from repro.kernels import ops as kops
        out = kops.paged_attention(q[:, 0], k_pages, v_pages, block_tables,
                                   pos, k_row, v_row, window=window)
        out = out.reshape(B, 1, -1)
    else:
        bidx = jnp.arange(B)
        ks = paged_gather(k_pages, block_tables).at[bidx, pos].set(k_row)
        vs = paged_gather(v_pages, block_tables).at[bidx, pos].set(v_row)
        out = decode_attention(q, ks, vs, pos, window=window)
    sc = acfg.scaling if acfg is not None else 1.0
    vs_ = (vera_shared or {})
    y = adapted(p["wo"], maybe(ad, "wo"), out, sc, vs_.get("wo"))
    return y, k_row, v_row


def suffix_attention(q, k_cache, v_cache, q_pos, *, window=None):
    """Multi-token attention against a cache with per-row positions.

    q: (B, L, H, hd); caches: (B, T, Hkv, hd); q_pos: (B, L) int32
    absolute position of each query token (the cache holds valid entries
    at [0, q_pos] per query). Generalizes ``decode_attention`` to L
    queries per row — the suffix-only prefill path, where every row
    resumes from its own cached-prefix offset and the shared (S,)/(T,)
    position vectors of ``blockwise_attention`` no longer fit.
    """
    B, L, H, hd = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, L, Hkv, G, hd)
    s = jnp.einsum("blhgd,bshd->bhgls", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * hd ** -0.5
    idx = jnp.arange(T)[None, None, :]              # (1, 1, T)
    valid = idx <= q_pos[:, :, None]                # (B, L, T)
    if window is not None:
        valid &= (q_pos[:, :, None] - idx) < window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgls,bshd->blhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, L, H * hd).astype(v_cache.dtype)


def attn_prefill_suffix_paged(cfg, p, ad, acfg, x, prefix_lens, k_pages,
                              v_pages, block_tables, *, window=None,
                              vera_shared=None):
    """Suffix-only prefill against a paged cache holding the prefix.

    x: (B, L, d) suffix embeddings; prefix_lens: (B,) cached prompt
    tokens per row; the pools already hold each row's prefix KV via its
    block table (possibly pages SHARED with other rows). The pools are
    read-only here — shared prefix pages must never be written — so the
    suffix K/V is inserted into the *gathered* logical view for
    attention and returned for the caller's post-scan scatter into the
    row's private pages.

    Returns (y, k_suf (B, L, Hkv, hd), v_suf (B, L, Hkv, hd)).
    """
    B, L, _ = x.shape
    q, k, v = _qkv(cfg, p, ad, acfg, x, x, vera_shared)
    pos = prefix_lens[:, None] + jnp.arange(L)[None, :]   # (B, L)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    k_suf = k.astype(k_pages.dtype)
    v_suf = v.astype(v_pages.dtype)
    bidx = jnp.arange(B)[:, None]
    ks = paged_gather(k_pages, block_tables).at[bidx, pos].set(k_suf)
    vs = paged_gather(v_pages, block_tables).at[bidx, pos].set(v_suf)
    out = suffix_attention(q, ks, vs, pos, window=window)
    sc = acfg.scaling if acfg is not None else 1.0
    vs_ = (vera_shared or {})
    y = adapted(p["wo"], maybe(ad, "wo"), out, sc, vs_.get("wo"))
    return y, k_suf, v_suf


def attn_forward(cfg, p, ad, acfg, x, positions, *, causal=True,
                 window=None, kv_x=None, rope=True, vera_shared=None):
    """Full-sequence attention (training / prefill / encoder / cross)."""
    B, S, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    q, k, v = _qkv(cfg, p, ad, acfg, x, kv_x, vera_shared)
    T = kv_x.shape[1]
    k_positions = positions if kv_x is x else jnp.arange(T)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, k_positions, cfg.rope_theta)
    if (cfg.attn_backend == "pallas" and kv_x is x
            and q.shape[-1] == v.shape[-1]):
        # Pallas flash kernel (§Perf it. 3c): scores never leave VMEM.
        # GQA: kv replicated across the group for the (B,H,S,d) layout.
        from repro.kernels import ops as kops
        G = q.shape[2] // k.shape[2]
        kr = jnp.repeat(k, G, axis=2).swapaxes(1, 2)
        vr = jnp.repeat(v, G, axis=2).swapaxes(1, 2)
        out = kops.flash_attention(
            q.swapaxes(1, 2), kr, vr, causal=causal, window=window,
            bq=min(512, S), bkv=min(512, T)).swapaxes(1, 2)
    else:
        out = blockwise_attention(q, k, v, positions, k_positions,
                                  causal=causal, window=window)
    out = out.reshape(B, S, -1)
    sc = acfg.scaling if acfg is not None else 1.0
    vs = (vera_shared or {})
    return adapted(p["wo"], maybe(ad, "wo"), out, sc, vs.get("wo")), (k, v)


def attn_decode(cfg, p, ad, acfg, x, pos, cache_k, cache_v, *,
                window=None, vera_shared=None):
    """One-step decode. x: (B, 1, d); pos: (B,). Returns (y, new_k, new_v)."""
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _qkv(cfg, p, ad, acfg, x, x, vera_shared)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    # insert into cache at pos (per batch row)
    upd = jax.vmap(lambda c, kn, i: jax.lax.dynamic_update_slice(
        c, kn, (i, 0, 0)))
    cache_k = upd(cache_k, k.astype(cache_k.dtype), pos)
    cache_v = upd(cache_v, v.astype(cache_v.dtype), pos)
    out = decode_attention(q, cache_k, cache_v, pos, window=window)
    sc = acfg.scaling if acfg is not None else 1.0
    vs = (vera_shared or {})
    y = adapted(p["wo"], maybe(ad, "wo"), out, sc, vs.get("wo"))
    return y, cache_k, cache_v


def cross_attn_decode(cfg, p, ad, acfg, x, k, v, *, vera_shared=None):
    """Decoder cross-attention against precomputed encoder K/V."""
    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    sc = acfg.scaling if acfg is not None else 1.0
    vs = (vera_shared or {})
    q = adapted(p["wq"], maybe(ad, "wq"), x, sc, vs.get("wq"))
    q = q.reshape(B, 1, H, hd)
    pos = jnp.full((B,), k.shape[1] - 1, jnp.int32)
    out = decode_attention(q, k, v, pos)
    return adapted(p["wo"], maybe(ad, "wo"), out, sc, vs.get("wo"))


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------

def init_mla(key, cfg, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 5)
    return {
        "wq_a": dense_init(ks[0], d, m.q_lora_rank, dtype),
        "q_a_norm": jnp.ones((m.q_lora_rank,), dtype),
        "wq_b": dense_init(ks[1], m.q_lora_rank,
                           H * (m.qk_nope_head_dim + m.qk_rope_head_dim), dtype),
        "wkv_a": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_a_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkv_b": dense_init(ks[3], m.kv_lora_rank,
                            H * (m.qk_nope_head_dim + m.v_head_dim), dtype),
        "wo": dense_init(ks[4], H * m.v_head_dim, d, dtype),
    }


def _mla_q(cfg, p, ad, acfg, x, positions, vera_shared):
    m, H = cfg.mla, cfg.n_heads
    sc = acfg.scaling if acfg is not None else 1.0
    vs = (vera_shared or {})
    cq = adapted(p["wq_a"], maybe(ad, "wq_a"), x, sc, vs.get("wq_a"))
    cq = rms_norm(cq, p["q_a_norm"], cfg.norm_eps)
    q = adapted(p["wq_b"], maybe(ad, "wq_b"), cq, sc, vs.get("wq_b"))
    q = q.reshape(*x.shape[:-1], H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    qn, qr = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    qr = apply_rope(qr, positions, cfg.rope_theta)
    return qn, qr


def _mla_latent(cfg, p, ad, acfg, x, positions, vera_shared):
    """Compute (normed) latent ckv and roped shared key."""
    m = cfg.mla
    sc = acfg.scaling if acfg is not None else 1.0
    vs = (vera_shared or {})
    ckv = adapted(p["wkv_a"], maybe(ad, "wkv_a"), x, sc, vs.get("wkv_a"))
    ckv, krope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    ckv = rms_norm(ckv, p["kv_a_norm"], cfg.norm_eps)
    krope = apply_rope(krope, positions, cfg.rope_theta)   # (B, S, rope)
    return ckv, krope


def mla_forward(cfg, p, ad, acfg, x, positions, *, vera_shared=None):
    """Full-sequence MLA. Returns (y, (ckv, krope)) for the latent cache."""
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    sc = acfg.scaling if acfg is not None else 1.0
    vs = (vera_shared or {})
    qn, qr = _mla_q(cfg, p, ad, acfg, x, positions, vera_shared)
    ckv, krope = _mla_latent(cfg, p, ad, acfg, x, positions, vera_shared)
    kv = adapted(p["wkv_b"], maybe(ad, "wkv_b"), ckv, sc, vs.get("wkv_b"))
    kv = kv.reshape(B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    kn, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    q = jnp.concatenate([qn, qr], axis=-1)
    k = jnp.concatenate(
        [kn, jnp.broadcast_to(krope[:, :, None],
                              (B, S, H, m.qk_rope_head_dim))], axis=-1)
    out = blockwise_attention(q, k, v, positions, positions, causal=True)
    out = out.reshape(B, S, H * m.v_head_dim)
    y = adapted(p["wo"], maybe(ad, "wo"), out, sc, vs.get("wo"))
    return y, (ckv, krope)


def mla_decode(cfg, p, ad, acfg, x, pos, cache_ckv, cache_krope, *,
               vera_shared=None):
    """One-step MLA decode against the latent cache.

    naive path: up-project every cached latent to per-head K/V each step.
    absorbed path (cfg.mla.absorbed_decode): fold W_UK into the query and
    W_UV into the output so scores/values are computed directly in latent
    space — the standard MLA inference optimization (§Perf).
    """
    m, H = cfg.mla, cfg.n_heads
    B = x.shape[0]
    Smax = cache_ckv.shape[1]
    sc = acfg.scaling if acfg is not None else 1.0
    vs = (vera_shared or {})
    qn, qr = _mla_q(cfg, p, ad, acfg, x, pos[:, None], vera_shared)
    ckv, krope = _mla_latent(cfg, p, ad, acfg, x, pos[:, None], vera_shared)
    upd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (i, 0)))
    cache_ckv = upd(cache_ckv, ckv.astype(cache_ckv.dtype), pos)
    cache_krope = upd(cache_krope, krope.astype(cache_krope.dtype), pos)

    # decode re-projects *cached* latents, so the adapter delta on wkv_b must
    # be merged into the weight (the forward path adds it on activations).
    wkv_b_eff = effective_weight(p["wkv_b"], maybe(ad, "wkv_b"), sc,
                                 vs.get("wkv_b"))
    wkv_b = wkv_b_eff.reshape(m.kv_lora_rank, H,
                              m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wkv_b[..., : m.qk_nope_head_dim]     # (r, H, nope)
    w_uv = wkv_b[..., m.qk_nope_head_dim:]      # (r, H, vd)
    idx = jnp.arange(Smax)[None, :]
    valid = idx <= pos[:, None]
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    qn = qn[:, 0].astype(jnp.float32)            # (B, H, nope)
    qr = qr[:, 0].astype(jnp.float32)            # (B, H, rope)
    c32 = cache_ckv.astype(jnp.float32)          # (B, S, r)
    kr32 = cache_krope.astype(jnp.float32)       # (B, S, rope)

    if m.absorbed_decode:
        # score_t = qnᵀ W_UK c_t + qrᵀ kr_t  — never materialize per-head K.
        q_lat = jnp.einsum("bhn,rhn->bhr", qn, w_uk.astype(jnp.float32))
        s = jnp.einsum("bhr,bsr->bhs", q_lat, c32)
        s = s + jnp.einsum("bhr,bsr->bhs", qr, kr32)
        s = jnp.where(valid[:, None], s * scale, NEG_INF)
        prob = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhs,bsr->bhr", prob, c32)
        out = jnp.einsum("bhr,rhv->bhv", o_lat, w_uv.astype(jnp.float32))
    else:
        kv = jnp.einsum("bsr,rhx->bshx", c32,
                        wkv_b.astype(jnp.float32))
        kn, v = kv[..., : m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim:]
        s = jnp.einsum("bhn,bshn->bhs", qn, kn)
        s = s + jnp.einsum("bhr,bsr->bhs", qr, kr32)
        s = jnp.where(valid[:, None], s * scale, NEG_INF)
        prob = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhs,bshv->bhv", prob, v)

    out = out.reshape(B, 1, H * m.v_head_dim).astype(x.dtype)
    y = adapted(p["wo"], maybe(ad, "wo"), out, sc, vs.get("wo"))
    return y, cache_ckv, cache_krope
