"""Mamba2 (SSD) — Zamba2 backbone block.

State-space duality block with scalar-per-head decay. Training/prefill uses
the same chunked-scan strategy as Mamba1 with per-head outer-product state
``(n_heads, head_dim, d_state)``; decode is the single-step recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import adapted, dense_init, maybe, rms_norm
from repro.models.mamba import _assoc_scan_chunk, causal_conv, conv_step


def _dims(cfg):
    s = cfg.ssm
    di = cfg.d_inner
    nh = di // s.head_dim
    conv_dim = di + 2 * s.n_groups * s.d_state
    return s, di, nh, conv_dim


def init_mamba2(key, cfg, dtype):
    s, di, nh, conv_dim = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * s.n_groups * s.d_state + nh
    return {
        "in_proj": dense_init(ks[0], d, d_in_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim), jnp.float32)
                   * s.d_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[3], di, d, dtype),
    }


def _split_in_proj(cfg, zxbcdt):
    s, di, nh, conv_dim = _dims(cfg)
    gs = s.n_groups * s.d_state
    z, x, B, C, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + gs,
                                        2 * di + 2 * gs], axis=-1)
    return z, jnp.concatenate([x, B, C], axis=-1), dt


def _post_conv(cfg, xbc):
    s, di, nh, _ = _dims(cfg)
    gs = s.n_groups * s.d_state
    x, B, C = jnp.split(xbc, [di, di + gs], axis=-1)
    lead = x.shape[:-1]
    x = x.reshape(*lead, nh, s.head_dim)
    B = B.reshape(*lead, s.n_groups, s.d_state)
    C = C.reshape(*lead, s.n_groups, s.d_state)
    # broadcast groups over heads
    rep = nh // s.n_groups
    B = jnp.repeat(B, rep, axis=-2)
    C = jnp.repeat(C, rep, axis=-2)
    return x.astype(jnp.float32), B.astype(jnp.float32), C.astype(jnp.float32)


def ssd_scan(dt, xh, Bm, C, A, chunk):
    """Per-head outer-product SSM — fused chunked form (§Perf it. 1).

    dt: (B, S, nh); xh: (B, S, nh, hd); Bm, C: (B, S, nh, ds); A: (nh,).
    The rank-5 (B, S, nh, hd, ds) input tensor is computed per chunk inside
    the scan body, never materialized for the full sequence. Returns
    y (B, S, nh, hd) f32 and final state (B, nh, hd, ds).
    """
    Bsz, S, nh = dt.shape
    hd = xh.shape[-1]
    ds = Bm.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = (S + pad) // chunk
    dtc = dt.reshape(Bsz, n, chunk, nh).swapaxes(0, 1)
    xc = xh.reshape(Bsz, n, chunk, nh, hd).swapaxes(0, 1)
    Bc = Bm.reshape(Bsz, n, chunk, nh, ds).swapaxes(0, 1)
    Cc = C.reshape(Bsz, n, chunk, nh, ds).swapaxes(0, 1)

    def body(h, inp):
        dti, xi, Bi, Ci = inp                               # per chunk
        ai = jnp.exp(dti * A)                               # (B, c, nh)
        bi = (dti[..., None] * xi)[..., None] * Bi[..., None, :]
        a4 = ai[..., None, None]
        acum, bcum = _assoc_scan_chunk(a4, bi)
        h_all = acum * h[:, None] + bcum                    # (B, c, nh, hd, ds)
        y = jnp.einsum("bchds,bchs->bchd", h_all, Ci)
        return h_all[:, -1], y

    h0 = jnp.zeros((Bsz, nh, hd, ds), jnp.float32)
    h_fin, ys = jax.lax.scan(body, h0, (dtc, xc, Bc, Cc))
    y = ys.swapaxes(0, 1).reshape(Bsz, S + pad, nh, hd)[:, :S]
    return y, h_fin


def mamba2_forward(cfg, p, ad, acfg, x, *, vera_shared=None):
    """Full-sequence Mamba2. Returns (y, final_state, conv_tail)."""
    s, di, nh, conv_dim = _dims(cfg)
    sc = acfg.scaling if acfg is not None else 1.0
    vs = (vera_shared or {})
    zxbcdt = adapted(p["in_proj"], maybe(ad, "in_proj"), x, sc,
                     vs.get("in_proj"))
    z, xbc_pre, dt = _split_in_proj(cfg, zxbcdt)
    xbc = causal_conv(xbc_pre, jax.lax.stop_gradient(p["conv_w"]),
                      jax.lax.stop_gradient(p["conv_b"]))
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xh, B, C = _post_conv(cfg, xbc)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, S, nh)
    A = -jnp.exp(p["A_log"])                                # (nh,)
    if s.backend == "pallas":
        # fused SSD kernel: per-head outer-product state in VMEM
        from repro.kernels import ops as kops
        nh = xh.shape[2]
        y, h = kops.ssd_scan_fused(dt, xh, B, C, A,
                                   bh=min(8, nh),
                                   chunk=min(s.chunk, dt.shape[1]))
    else:
        y, h = ssd_scan(dt, xh, B, C, A, s.chunk)
    y = y + p["D"][:, None] * xh
    y = y.reshape(*x.shape[:-1], di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    y = adapted(p["out_proj"], maybe(ad, "out_proj"), y, sc,
                vs.get("out_proj"))
    conv_tail = xbc_pre[:, -(s.d_conv - 1):]                # decode warm-start
    return y, h, conv_tail


def mamba2_step(cfg, p, ad, acfg, x, h, conv_buf, *, vera_shared=None):
    """One decode step. x: (B, 1, d); h: (B, nh, hd, ds)."""
    s, di, nh, conv_dim = _dims(cfg)
    sc = acfg.scaling if acfg is not None else 1.0
    vs = (vera_shared or {})
    zxbcdt = adapted(p["in_proj"], maybe(ad, "in_proj"), x[:, 0], sc,
                     vs.get("in_proj"))
    z, xbc, dt = _split_in_proj(cfg, zxbcdt)
    xbc, conv_buf = conv_step(xbc, conv_buf, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xh, B, C = _post_conv(cfg, xbc)                         # (B, nh, hd/ds)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                     # (B, nh)
    h = a[..., None, None] * h + (dt[..., None] * xh)[..., None] \
        * B[..., None, :]
    y = jnp.einsum("bhds,bhs->bhd", h, C)
    y = y + p["D"][:, None] * xh
    y = y.reshape(x.shape[0], di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    y = adapted(p["out_proj"], maybe(ad, "out_proj"), y, sc,
                vs.get("out_proj"))
    return y[:, None], h, conv_buf
