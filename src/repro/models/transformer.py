"""Model assembly: stacked-and-scanned blocks → full architectures.

Layers are grouped into *segments* of identical block structure; each segment
is a stacked pytree (leading axis = layer index) consumed by ``lax.scan``.
This keeps HLO size and compile time bounded for 61-layer models SPMD-lowered
to 512 devices on a single CPU host.

Entry points
------------
``init_model``     parameters (usable under ``jax.eval_shape`` for dry-runs)
``loss_fn``        training loss (chunked CE + MoE aux + optional MTP)
``prefill``        full-sequence forward that also returns the decode cache
``decode_step``    one-token step against the cache
``decode_scan``    fused multi-tick greedy decode (dense cache)
``decode_scan_paged``  fused multi-tick greedy decode (paged cache)
``init_cache``     cache ShapeDtypeStruct-compatible zeros
``encode``         bidirectional encoder + classification head (RoBERTa path)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.blocks import (block_decode, block_forward, init_block,
                                 block_prefill_suffix)
from repro.models.common import chunked_cross_entropy, embed_init, maybe, rms_norm


# ---------------------------------------------------------------------------
# Segmentation
# ---------------------------------------------------------------------------

def segments(cfg):
    """List of homogeneous layer segments: dicts with kind / n / moe.

    ``hybrid`` segments scan super-blocks of (attn_every - 1) mamba2 layers
    followed by one occurrence of the *shared* attention block.
    """
    if cfg.family == "hybrid":
        assert cfg.n_layers % cfg.attn_every == 0
        return [{"kind": "hybrid", "n": cfg.n_layers // cfg.attn_every,
                 "inner": cfg.attn_every - 1, "moe": False}]
    if cfg.family == "ssm":
        kind = "mamba" if cfg.ssm.version == 1 else "mamba2"
        return [{"kind": kind, "n": cfg.n_layers, "moe": False}]
    kind = "mla" if cfg.mla is not None else (
        "dec_attn" if cfg.enc_dec else "attn")
    if cfg.moe is not None:
        segs = []
        nd = cfg.moe.n_dense_layers
        if nd:
            segs.append({"kind": kind, "n": nd, "moe": False})
        segs.append({"kind": kind, "n": cfg.n_layers - nd, "moe": True})
        return segs
    return [{"kind": kind, "n": cfg.n_layers, "moe": False}]


def _stack_init(key, n, init_one):
    """vmap an init function over n split keys → stacked params."""
    return jax.vmap(init_one)(jax.random.split(key, n))


def init_model(key, cfg, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 8)
    p = {"embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
         "ln_f": jnp.ones((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        p["head"] = embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype).T
    segs = segments(cfg)
    seg_keys = jax.random.split(ks[2], len(segs))
    stacked = []
    for seg, sk in zip(segs, seg_keys):
        if seg["kind"] == "hybrid":
            k1, k2 = jax.random.split(sk)
            stacked.append({"mamba": _stack_init(
                k1, seg["n"], lambda k: _stack_init(
                    k, seg["inner"],
                    lambda kk: init_block(kk, cfg, "mamba2", dtype)))})
            # the shared attention block: ONE weight set for all occurrences
            p["shared_attn"] = init_block(k2, cfg, "attn", dtype)
        else:
            stacked.append(_stack_init(
                sk, seg["n"],
                functools.partial(init_block, cfg=cfg, kind=seg["kind"],
                                  dtype=dtype, moe_layer=seg["moe"])))
    p["segments"] = stacked
    if cfg.enc_dec:
        p["enc"] = {
            "segments": [_stack_init(
                ks[3], cfg.n_enc_layers,
                functools.partial(init_block, cfg=cfg, kind="enc_attn",
                                  dtype=dtype))],
            "ln_f": jnp.ones((cfg.d_model,), dtype),
        }
    if cfg.mtp_depth:
        k1, k2 = jax.random.split(ks[4])
        p["mtp"] = {
            "proj": (jax.random.normal(k1, (2 * cfg.d_model, cfg.d_model),
                                       jnp.float32)
                     * (2 * cfg.d_model) ** -0.5).astype(dtype),
            "ln_h": jnp.ones((cfg.d_model,), dtype),
            "ln_e": jnp.ones((cfg.d_model,), dtype),
            "block": init_block(
                k2, cfg, "mla" if cfg.mla is not None else "attn", dtype,
                moe_layer=False),
        }
    return p


# ---------------------------------------------------------------------------
# Segment execution
# ---------------------------------------------------------------------------

def _seg_adapters(adapters, i):
    if adapters is None:
        return None
    return adapters["segments"][i]


# §Perf it. 3a (measured trade-off): sequence-parallel residual HALVES
# per-device HBM temp (49.2 → 29.9 GiB on deepseek-7b train_4k — the scan
# backward carry shrinks by the model-axis factor) but GSPMD's per-layer
# gather/scatter resharding RAISES weighted HBM traffic 2.4× and the
# collective term 3.3×. Opt-in: enable when capacity, not bandwidth, is
# the binding constraint.
SEQ_PARALLEL = False


def _seq_shard(x):
    """Sequence parallelism (§Perf hillclimb 3): constrain the residual
    stream to be sequence-sharded over the "model" axis at block
    boundaries. Norms/elementwise run sequence-parallel; GSPMD inserts the
    all-gather before attention/matmuls and reduce-scatters after — and,
    critically, the scan's backward CARRY is stored 1/model-size as large.
    No-op off the production mesh (model axis absent or S not divisible).
    """
    if not SEQ_PARALLEL:
        return x
    try:
        from jax._src import mesh as mesh_lib
        env_mesh = mesh_lib.thread_resources.env.physical_mesh
        if env_mesh.empty or "model" not in env_mesh.axis_names:
            return x
        ms = env_mesh.shape["model"]
        if ms <= 1 or x.shape[-2] % ms != 0:
            return x
        from jax.sharding import PartitionSpec as P
        spec = P(*((None,) * (x.ndim - 2) + ("model", None)))
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:  # noqa: BLE001
        return x


def _scan_seg(cfg, seg, sp, sad, acfg, x, positions, *, window, enc_out,
              vera_shared, shared_attn=None, collect=False, remat=False):
    """Run one segment. Returns (x, aux, caches|None)."""
    aux0 = jnp.zeros((), jnp.float32)
    ckpt = jax.checkpoint if remat else (lambda f: f)

    if seg["kind"] == "hybrid":
        def body(carry, xs):
            x, aux = carry
            mp, mad, aad = xs

            def inner(c, ixs):
                xi, auxi = c
                ip, iad = ixs if mad is not None else (ixs, None)
                xi, cache, a = block_forward(cfg, ip, iad, acfg, xi,
                                             positions, "mamba2",
                                             vera_shared=vera_shared)
                return (xi, auxi + a), cache if collect else None

            (x, aux), mcaches = jax.lax.scan(
                inner, (x, aux), (mp, mad) if mad is not None else mp)
            x, acache, a = block_forward(cfg, shared_attn, aad, acfg, x,
                                         positions, "attn", window=window,
                                         vera_shared=vera_shared)
            out = (mcaches, acache) if collect else None
            return (x, aux + a), out

        mad = maybe(sad, "mamba")
        aad = maybe(sad, "attn")
        if sad is None:
            # scan needs matching xs structure; wrap params-only
            def body_np(carry, mp):
                return body(carry, (mp, None, None))
            (x, aux), caches = jax.lax.scan(ckpt(body_np), (x, aux0),
                                            sp["mamba"])
        else:
            (x, aux), caches = jax.lax.scan(
                ckpt(body), (x, aux0), (sp["mamba"], mad, aad))
        return x, aux, caches

    def body(carry, xs):
        x, aux = carry
        p, ad = xs if sad is not None else (xs, None)
        x = _seq_shard(x)
        x, cache, a = block_forward(cfg, p, ad, acfg, x, positions,
                                    seg["kind"], window=window,
                                    enc_out=enc_out, vera_shared=vera_shared)
        return (x, aux + a), cache if collect else None

    xs = (sp, sad) if sad is not None else sp
    (x, aux), caches = jax.lax.scan(ckpt(body), (x, aux0), xs)
    return x, aux, caches


def _run_encoder(cfg, params, adapters, acfg, frames, vera_shared):
    """Whisper encoder over stub frame embeddings (B, enc_seq, d)."""
    ep = params["enc"]
    ead = maybe(adapters, "enc") if adapters is not None else None
    pos = jnp.arange(frames.shape[1])
    x = frames
    seg = {"kind": "enc_attn", "n": cfg.n_enc_layers, "moe": False}
    sad = ead["segments"][0] if ead is not None else None
    x, _, _ = _scan_seg(cfg, seg, ep["segments"][0], sad, acfg, x, pos,
                        window=None, enc_out=None, vera_shared=vera_shared)
    return rms_norm(x, ep["ln_f"], cfg.norm_eps)


def forward_hidden(cfg, params, adapters, acfg, tokens, *, enc_frames=None,
                   window=None, collect=False, remat=False):
    """Token ids → final hidden states. Returns (hidden, aux, caches, enc_out)."""
    vera_shared = maybe(adapters, "vera_shared") if adapters else None
    x = params["embed"][tokens]
    positions = jnp.arange(tokens.shape[1])
    enc_out = None
    if cfg.enc_dec:
        enc_out = _run_encoder(cfg, params, adapters, acfg, enc_frames,
                               vera_shared)
    window = window if window is not None else cfg.sliding_window
    aux = jnp.zeros((), jnp.float32)
    caches = []
    for i, seg in enumerate(segments(cfg)):
        x, a, c = _scan_seg(cfg, seg, params["segments"][i],
                            _seg_adapters(adapters, i), acfg, x, positions,
                            window=window, enc_out=enc_out,
                            vera_shared=vera_shared,
                            shared_attn=params.get("shared_attn"),
                            collect=collect, remat=remat)
        aux = aux + a
        caches.append(c)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x, aux, (caches if collect else None), enc_out


def head_weight(cfg, params):
    return params["embed"].T if cfg.tie_embeddings else params["head"]


# ---------------------------------------------------------------------------
# Training loss
# ---------------------------------------------------------------------------

def loss_fn(cfg, params, adapters, acfg, batch, *, mtp_coef=0.3,
            remat=False):
    """batch: {"tokens": (B, S), "labels": (B, S), "mask"?: (B, S),
    "frames"?: (B, enc_seq, d)}."""
    hidden, aux, _, _ = forward_hidden(cfg, params, adapters, acfg,
                                       batch["tokens"],
                                       enc_frames=batch.get("frames"),
                                       remat=remat)
    w_head = jax.lax.stop_gradient(head_weight(cfg, params))
    mask = batch.get("mask")
    loss = chunked_cross_entropy(hidden, w_head, batch["labels"], mask)
    if cfg.mtp_depth and "mtp" in params:
        loss = loss + mtp_coef * _mtp_loss(cfg, params, adapters, acfg,
                                           hidden, batch)
    return loss + aux


def _mtp_loss(cfg, params, adapters, acfg, hidden, batch):
    """Depth-1 multi-token prediction (DeepSeek-V3 §MTP).

    h'_t = Block(W_p [RMSNorm(h_t); RMSNorm(Emb(y_t))]) predicts y_{t+1},
    i.e. token t+2 of the original stream. Shares embedding and output head
    with the main model.
    """
    mp = params["mtp"]
    labels = batch["labels"]
    emb = params["embed"][labels]                   # Emb(y_t), (B, S, d)
    h = jnp.concatenate([rms_norm(hidden, mp["ln_h"], cfg.norm_eps),
                         rms_norm(emb, mp["ln_e"], cfg.norm_eps)], axis=-1)
    h = h @ jax.lax.stop_gradient(mp["proj"])
    positions = jnp.arange(h.shape[1])
    kind = "mla" if cfg.mla is not None else "attn"
    h, _, _ = block_forward(cfg, mp["block"], None, acfg, h, positions, kind)
    # next-next-token targets
    y2 = jnp.roll(labels, -1, axis=1)
    mask = batch.get("mask")
    mask = jnp.ones_like(labels, jnp.float32) if mask is None else mask
    mask = mask.at[:, -1].set(0.0)                  # last shift is invalid
    w_head = jax.lax.stop_gradient(head_weight(cfg, params))
    return chunked_cross_entropy(h, w_head, y2, mask)


# ---------------------------------------------------------------------------
# Cache init / prefill / decode
# ---------------------------------------------------------------------------

def _zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def init_cache(cfg, batch_size, max_seq, dtype=jnp.bfloat16, enc_seq=None):
    """Decode cache pytree, mirroring the per-segment scan layout."""
    B = batch_size
    hd = cfg.resolved_head_dim
    Hkv = cfg.n_kv_heads
    caches = []
    for seg in segments(cfg):
        n = seg["n"]
        if seg["kind"] == "hybrid":
            s = cfg.ssm
            nh = cfg.d_inner // s.head_dim
            conv_dim = cfg.d_inner + 2 * s.n_groups * s.d_state
            m = {"h": _zeros((n, seg["inner"], B, nh, s.head_dim, s.d_state),
                             jnp.float32),
                 "conv": _zeros((n, seg["inner"], B, s.d_conv - 1, conv_dim),
                                dtype)}
            a = {"k": _zeros((n, B, max_seq, Hkv, hd), dtype),
                 "v": _zeros((n, B, max_seq, Hkv, hd), dtype)}
            caches.append((m, a))
        elif seg["kind"] == "mamba":
            caches.append({"h": _zeros((n, B, cfg.d_inner, cfg.ssm.d_state),
                                       jnp.float32),
                           "conv": _zeros((n, B, cfg.ssm.d_conv - 1,
                                           cfg.d_inner), dtype)})
        elif seg["kind"] == "mamba2":
            s = cfg.ssm
            nh = cfg.d_inner // s.head_dim
            conv_dim = cfg.d_inner + 2 * s.n_groups * s.d_state
            caches.append({"h": _zeros((n, B, nh, s.head_dim, s.d_state),
                                       jnp.float32),
                           "conv": _zeros((n, B, s.d_conv - 1, conv_dim),
                                          dtype)})
        elif seg["kind"] == "mla":
            m = cfg.mla
            caches.append({"ckv": _zeros((n, B, max_seq, m.kv_lora_rank),
                                         dtype),
                           "krope": _zeros((n, B, max_seq, m.qk_rope_head_dim),
                                           dtype)})
        else:
            c = {"k": _zeros((n, B, max_seq, Hkv, hd), dtype),
                 "v": _zeros((n, B, max_seq, Hkv, hd), dtype)}
            if seg["kind"] == "dec_attn":
                es = enc_seq or cfg.enc_seq
                c["cross_k"] = _zeros((n, B, es, Hkv, hd), dtype)
                c["cross_v"] = _zeros((n, B, es, Hkv, hd), dtype)
            caches.append(c)
    return caches


def paged_unsupported_reason(cfg):
    """Why a config cannot use the paged KV layout (None when it can).

    Paging applies to decoder-attention K/V; SSM/hybrid state caches have
    no sequence axis to page and enc-dec / MLA decode are not wired.
    """
    if cfg.mla is not None:
        return "MLA latent cache has no paged decode path"
    bad = [s["kind"] for s in segments(cfg) if s["kind"] != "attn"]
    if bad:
        return f"segment kinds {sorted(set(bad))} have no paged layout"
    return None


def init_paged_cache(cfg, n_pages, page_size, dtype=jnp.bfloat16):
    """Paged decode cache: per segment a K/V page pool
    (n_layers, n_pages, page_size, Hkv, hd) shared by every sequence.

    Logical position t of a row lives at physical page
    ``block_table[row, t // page_size]``, offset ``t % page_size``; the
    block table itself is host state (``repro.serving.scheduler``) passed
    into ``decode_step_paged`` / ``prefill_paged`` as a traced argument.
    """
    reason = paged_unsupported_reason(cfg)
    if reason is not None:
        raise NotImplementedError(reason)
    hd = cfg.resolved_head_dim
    Hkv = cfg.n_kv_heads
    return [{"k": _zeros((seg["n"], n_pages, page_size, Hkv, hd), dtype),
             "v": _zeros((seg["n"], n_pages, page_size, Hkv, hd), dtype)}
            for seg in segments(cfg)]


def _scatter_pages(pages, src, page_ids, page_size):
    """Write prefill K/V straight into the pool.

    pages: (n, n_pages, page, ...); src: (n, G, L, ...) with
    L % page == 0; page_ids: (G, L // page) physical destination per
    logical page (write-off page 0 absorbs padded rows).
    """
    n, G, L = src.shape[:3]
    npg = L // page_size
    srcp = src.reshape(n, G * npg, page_size, *src.shape[3:])
    return pages.at[:, page_ids.reshape(-1)].set(srcp.astype(pages.dtype))


def prefill_paged(cfg, params, adapters, acfg, tokens, lengths, cache,
                  block_tables, *, window=None):
    """Chunked batched prefill: one forward over a length-bucketed group,
    K/V written straight into pages.

    tokens: (G, L) prompts right-padded to the bucket length (L a
    multiple of the page size); lengths: (G,) true prompt lengths;
    block_tables: (G, P) physical page ids (unused/padding entries 0).
    Returns (next-token logits (G, V) f32, updated cache). Causal masking
    makes the padded positions invisible to the real ones, so per-row
    results are exactly what a batch-1 unpadded prefill produces.
    """
    hidden, _, built, _ = forward_hidden(cfg, params, adapters, acfg,
                                         tokens, window=window, collect=True)
    G, L = tokens.shape
    page = cache[0]["k"].shape[2]
    npg = L // page
    new_cache = []
    for e, b in zip(cache, built):
        ids = block_tables[:, :npg]
        new_cache.append(
            {"k": _scatter_pages(e["k"], b["k"], ids, page),
             "v": _scatter_pages(e["v"], b["v"], ids, page)})
    last = jnp.take_along_axis(
        hidden, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)
    logits = (last[:, 0] @ head_weight(cfg, params)).astype(jnp.float32)
    return logits, new_cache


def prefill_paged_suffix(cfg, params, adapters, acfg, tokens, lengths,
                         prefix_lens, cache, block_tables, dst_pages, *,
                         window=None):
    """Suffix-only prefill for rows whose prompt prefix is already paged
    in (the prefix-cache hit path — see ``repro.serving.prefix``).

    tokens: (G, L) divergent suffixes right-padded to the bucket length
    (L a multiple of the page size); lengths: (G,) true suffix lengths
    (>= 1); prefix_lens: (G,) cached tokens per row — row g's suffix
    token j sits at absolute position ``prefix_lens[g] + j``, and its
    attention reads the prefix KV through ``block_tables`` (G, P).
    dst_pages: (G, L // page) PRIVATE physical pages receiving the
    suffix K/V — 0 (the write-off page) for padding rows and for
    full-prompt hits, whose one "suffix" token's K/V already sits in the
    shared pages. Shared prefix pages are never written: the pools ride
    the layer scans read-only and only ``dst_pages`` is scattered.

    Returns (next-token logits (G, V) f32, updated cache).
    """
    vera_shared = maybe(adapters, "vera_shared") if adapters else None
    window = window if window is not None else cfg.sliding_window
    x = params["embed"][tokens]
    page = cache[0]["k"].shape[2]
    new_cache = []
    for i, seg in enumerate(segments(cfg)):
        sp = params["segments"][i]
        sad = _seg_adapters(adapters, i)

        def body(x, xs):
            if sad is not None:
                p, ad, ci = xs
            else:
                p, ci = xs
                ad = None
            x, rows = block_prefill_suffix(
                cfg, p, ad, acfg, x, prefix_lens, ci,
                block_tables=block_tables, window=window,
                vera_shared=vera_shared)
            return x, rows

        xs = (sp, sad, cache[i]) if sad is not None else (sp, cache[i])
        x, rows = jax.lax.scan(body, x, xs)  # rows["k"]: (n, G, L, Hkv, hd)
        new_cache.append(
            {"k": _scatter_pages(cache[i]["k"], rows["k"], dst_pages, page),
             "v": _scatter_pages(cache[i]["v"], rows["v"], dst_pages, page)})
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    last = jnp.take_along_axis(
        x, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)
    logits = (last[:, 0] @ head_weight(cfg, params)).astype(jnp.float32)
    return logits, new_cache


def _decode_rows_paged(cfg, params, adapters, acfg, token, pos, cache,
                       block_tables, *, window=None, attn_backend="xla"):
    """Shared per-tick core of the paged decode paths: embed → layer
    scans (page pools ride as READ-ONLY xs) → logits, plus each
    segment's new K/V rows (n, B, Hkv, hd), NOT yet committed to the
    pools — callers commit with ``_commit_rows``."""
    vera_shared = maybe(adapters, "vera_shared") if adapters else None
    window = window if window is not None else cfg.sliding_window
    paged = {"block_tables": block_tables, "attn_backend": attn_backend}
    x = params["embed"][token]
    rows_out = []
    for i, seg in enumerate(segments(cfg)):
        sp = params["segments"][i]
        sad = _seg_adapters(adapters, i)

        def body(x, xs):
            if sad is not None:
                p, ad, ci = xs
            else:
                p, ci = xs
                ad = None
            x, rows = block_decode(cfg, p, ad, acfg, x, pos, ci, seg["kind"],
                                   window=window, vera_shared=vera_shared,
                                   paged=paged)
            return x, rows

        xs = (sp, sad, cache[i]) if sad is not None else (sp, cache[i])
        x, rows = jax.lax.scan(body, x, xs)     # rows: (n, B, Hkv, hd)
        rows_out.append(rows)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ head_weight(cfg, params)
    return logits.astype(jnp.float32), rows_out


def _commit_rows(cache, rows, block_tables, pos, write_mask=None):
    """Commit every segment's new K/V rows into the pools: one scatter
    per pool at (physical page of pos, pos % page). ``write_mask``
    ((B,) bool, optional) redirects masked-off rows to the write-off
    page 0 at offset 0 — finished/idle rows of a fused scan stop
    writing real pages (the write-off absorbs them harmlessly)."""
    page = cache[0]["k"].shape[2]
    phys = jnp.take_along_axis(block_tables, (pos // page)[:, None],
                               axis=1)[:, 0]
    off = pos % page
    if write_mask is not None:
        phys = jnp.where(write_mask, phys, 0)
        off = jnp.where(write_mask, off, 0)
    return [{"k": e["k"].at[:, phys, off].set(r["k"]),
             "v": e["v"].at[:, phys, off].set(r["v"])}
            for e, r in zip(cache, rows)]


def decode_step_paged(cfg, params, adapters, acfg, token, pos, cache,
                      block_tables, *, window=None, attn_backend="xla"):
    """One decode step against the paged cache (``init_paged_cache``).

    token: (B, 1) int32; pos: (B,); block_tables: (B, P') — P' may be a
    prefix of the full table (the serving engine buckets it to the
    longest active sequence so short batches never attend over max_seq).
    Returns (logits (B, 1, V) f32, new cache).

    The page pools ride the layer scan as READ-ONLY xs; each layer emits
    its new K/V row and all rows are committed afterwards with one
    scatter per pool — with the cache donated into the jitted step this
    updates pages in place instead of rebuilding the pool every token.
    """
    logits, rows = _decode_rows_paged(cfg, params, adapters, acfg, token,
                                      pos, cache, block_tables,
                                      window=window,
                                      attn_backend=attn_backend)
    return logits, _commit_rows(cache, rows, block_tables, pos)


def _advance_tick(logits, token, pos, budget, active, eos_id, pad_id):
    """Shared tick epilogue of the fused scan twins (paged and dense —
    one definition, so the paired paths cannot drift): greedy-sample,
    pad finished rows, decrement budgets (EOS zeroes a row's budget
    AFTER its token counts), freeze finished rows' token/pos carry.
    Returns (token, pos, budget, emitted)."""
    nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
    emitted = jnp.where(active, nxt, jnp.int32(pad_id))
    budget = jnp.maximum(budget - active.astype(budget.dtype), 0)
    if eos_id is not None:
        budget = jnp.where(active & (emitted == eos_id), 0, budget)
    token = jnp.where(active[:, None], nxt[:, None], token)
    pos = pos + active.astype(pos.dtype)
    return token, pos, budget, emitted


def decode_scan_paged(cfg, params, adapters, acfg, token, pos, budget,
                      cache, block_tables, *, n_ticks, eos_id=None,
                      pad_id=0, window=None, attn_backend="xla"):
    """Up to ``n_ticks`` greedy decode ticks fused into ONE ``lax.scan``
    — token sampling, position advance, and the page-pool commit all
    stay on device, so the host pays one dispatch (and one sync) per
    n_ticks tokens instead of per token.

    token: (B, 1) int32 last sampled token per row; pos: (B,) next cache
    write position; budget: (B,) int32 decode tokens each row may still
    emit (0 = finished or idle row). Per tick, rows with budget > 0
    decode one token; the commit moves INSIDE the loop so K/V written at
    tick t is attended at tick t+1 (tick t itself sees the row through
    the in-attention append). Finished rows emit ``pad_id``, freeze
    their token/pos carry, and redirect their pool writes to the
    write-off page; emitting ``eos_id`` zeroes the row's budget after
    the token counts. ``block_tables`` must cover the deepest position
    any row can reach within the window (the engine buckets them to
    max over rows of pos + min(n_ticks, budget)).

    Returns (tokens (n_ticks, B) int32, token, pos, budget, cache) —
    the trailing carries re-enter the next fused scan unchanged.
    """
    def tick(carry, _):
        token, pos, budget, cache = carry
        active = budget > 0
        logits, rows = _decode_rows_paged(cfg, params, adapters, acfg,
                                          token, pos, cache, block_tables,
                                          window=window,
                                          attn_backend=attn_backend)
        cache = _commit_rows(cache, rows, block_tables, pos,
                             write_mask=active)
        token, pos, budget, emitted = _advance_tick(
            logits, token, pos, budget, active, eos_id, pad_id)
        return (token, pos, budget, cache), emitted

    (token, pos, budget, cache), toks = jax.lax.scan(
        tick, (token, pos, budget, cache), None, length=n_ticks)
    return toks, token, pos, budget, cache


def _mask_cache_rows(new, old, keep):
    """Per-row cache select: keep[b] picks new vs old along the batch
    axis (axis 1 on every non-hybrid cache leaf)."""
    def one(n, o):
        shape = (1, keep.shape[0]) + (1,) * (n.ndim - 2)
        return jnp.where(keep.reshape(shape), n, o)
    return jax.tree_util.tree_map(one, new, old)


def decode_scan(cfg, params, adapters, acfg, token, pos, budget, cache, *,
                n_ticks, eos_id=None, pad_id=0, window=None):
    """Dense-layout fused multi-tick decode (``decode_scan_paged``'s
    fallback twin, same contract): up to ``n_ticks`` greedy ticks in one
    ``lax.scan`` against the ``init_cache`` layout. Finished rows emit
    ``pad_id`` and keep their cache rows untouched (a per-row select —
    the dense cache has no write-off page to redirect into)."""
    def tick(carry, _):
        token, pos, budget, cache = carry
        active = budget > 0
        logits, stepped = decode_step(cfg, params, adapters, acfg, token,
                                      pos, cache, window=window)
        cache = _mask_cache_rows(stepped, cache, active)
        token, pos, budget, emitted = _advance_tick(
            logits, token, pos, budget, active, eos_id, pad_id)
        return (token, pos, budget, cache), emitted

    (token, pos, budget, cache), toks = jax.lax.scan(
        tick, (token, pos, budget, cache), None, length=n_ticks)
    return toks, token, pos, budget, cache


def _fill_cache(cfg, empty, built, seq_len):
    """Copy prefill-produced K/V/state tensors into the fixed-size cache."""
    def place(dst, src):
        if dst.ndim == src.ndim:                    # full-size state (SSM h)
            return src.astype(dst.dtype)
        return dst  # handled explicitly below
    out = []
    for seg, e, b in zip(segments(cfg), empty, built):
        if seg["kind"] == "hybrid":
            em, ea = e
            bm, ba = b
            new_m = {"h": bm["h"].astype(em["h"].dtype),
                     "conv": bm["conv"].astype(em["conv"].dtype)}
            new_a = {
                "k": jax.lax.dynamic_update_slice(
                    ea["k"], ba["k"].astype(ea["k"].dtype), (0, 0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    ea["v"], ba["v"].astype(ea["v"].dtype), (0, 0, 0, 0, 0)),
            }
            out.append((new_m, new_a))
        elif seg["kind"] in ("mamba", "mamba2"):
            out.append({"h": b["h"].astype(e["h"].dtype),
                        "conv": b["conv"].astype(e["conv"].dtype)})
        else:
            new = {}
            for name, dst in e.items():
                src = b[name].astype(dst.dtype)
                if name.startswith("cross"):
                    new[name] = src                  # encoder K/V: exact size
                else:
                    start = (0,) * dst.ndim
                    new[name] = jax.lax.dynamic_update_slice(dst, src, start)
            out.append(new)
    return out


def prefill(cfg, params, adapters, acfg, tokens, max_seq, *, enc_frames=None,
            cache_dtype=jnp.bfloat16, window=None):
    """Process the prompt; returns (last-token logits, cache, enc_out)."""
    hidden, _, built, enc_out = forward_hidden(
        cfg, params, adapters, acfg, tokens, enc_frames=enc_frames,
        window=window, collect=True)
    S = tokens.shape[1]
    empty = init_cache(cfg, tokens.shape[0], max_seq, cache_dtype,
                       enc_seq=enc_frames.shape[1] if enc_frames is not None
                       else None)
    cache = _fill_cache(cfg, empty, built, S)
    logits = hidden[:, -1:] @ head_weight(cfg, params)
    return logits.astype(jnp.float32), cache, enc_out


def decode_step(cfg, params, adapters, acfg, token, pos, cache, *,
                window=None):
    """One decode step.

    token: (B, 1) int32; pos: (B,) index of this token. Returns
    (logits (B, 1, V) f32, new cache).
    """
    vera_shared = maybe(adapters, "vera_shared") if adapters else None
    window = window if window is not None else cfg.sliding_window
    x = params["embed"][token]
    new_caches = []
    for i, seg in enumerate(segments(cfg)):
        sp = params["segments"][i]
        sad = _seg_adapters(adapters, i)
        c = cache[i]
        if seg["kind"] == "hybrid":
            def body(x, xs):
                mp, mad, aad, mc, ac = xs

                def inner(xi, ixs):
                    ip, iad, ic = ixs
                    xi, nc = block_decode(cfg, ip, iad, acfg, xi, pos, ic,
                                          "mamba2", vera_shared=vera_shared)
                    return xi, nc

                x, new_mc = jax.lax.scan(inner, x, (mp, mad, mc))
                x, new_ac = block_decode(cfg, params["shared_attn"], aad,
                                         acfg, x, pos, ac, "attn",
                                         window=window,
                                         vera_shared=vera_shared)
                return x, (new_mc, new_ac)

            mad = maybe(sad, "mamba")
            aad = maybe(sad, "attn")
            if sad is None:
                def body_np(x, xs):
                    mp, mc, ac = xs
                    return body(x, (mp, None, None, mc, ac))
                x, nc = jax.lax.scan(body_np, x, (sp["mamba"], c[0], c[1]))
            else:
                x, nc = jax.lax.scan(body, x, (sp["mamba"], mad, aad,
                                               c[0], c[1]))
            new_caches.append(nc)
        else:
            def body(x, xs):
                if sad is not None:
                    p, ad, ci = xs
                else:
                    p, ci = xs
                    ad = None
                x, nc = block_decode(cfg, p, ad, acfg, x, pos, ci,
                                     seg["kind"], window=window,
                                     vera_shared=vera_shared)
                return x, nc

            xs = (sp, sad, c) if sad is not None else (sp, c)
            x, nc = jax.lax.scan(body, x, xs)
            new_caches.append(nc)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ head_weight(cfg, params)
    return logits.astype(jnp.float32), new_caches


# ---------------------------------------------------------------------------
# Encoder-classifier path (RoBERTa — the paper's NLU backbone)
# ---------------------------------------------------------------------------

def init_classifier(key, cfg, n_classes, dtype=jnp.float32):
    return {"w": (jax.random.normal(key, (cfg.d_model, n_classes),
                                    jnp.float32)
                  * cfg.d_model ** -0.5).astype(dtype),
            "b": jnp.zeros((n_classes,), dtype)}


def encode_logits(cfg, params, adapters, acfg, cls_head, tokens):
    """Bidirectional encode → first-token pooled classification logits."""
    hidden, aux, _, _ = forward_hidden(cfg, params, adapters, acfg, tokens)
    pooled = hidden[:, 0].astype(jnp.float32)
    return pooled @ cls_head["w"] + cls_head["b"], aux


def classifier_loss(cfg, params, adapters, acfg, cls_head, batch):
    logits, aux = encode_logits(cfg, params, adapters, acfg, cls_head,
                                batch["tokens"])
    labels = batch["label"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    return nll + aux
