"""Block assembly: init/forward per block kind + adapter shape specs.

Block kinds
-----------
``attn``        pre-norm attention + (MLP | MoE)            (dense/moe/vlm)
``mla``         pre-norm MLA + (MLP | MoE)                  (deepseek-v3)
``mamba``       pre-norm Mamba1 mixer                       (falcon-mamba)
``mamba2``      pre-norm Mamba2 mixer                       (zamba2)
``enc_attn``    bidirectional attention + MLP               (whisper encoder)
``dec_attn``    causal self-attn + cross-attn + MLP         (whisper decoder)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import (attn_decode, attn_decode_paged,
                                    attn_forward, attn_prefill_suffix_paged,
                                    cross_attn_decode, init_attention,
                                    init_mla, mla_decode, mla_forward)
from repro.models.mamba import init_mamba, mamba_forward, mamba_step
from repro.models.mamba2 import init_mamba2, mamba2_forward, mamba2_step
from repro.models.mlp import init_mlp, mlp_forward
from repro.models.moe import init_moe, moe_forward
from repro.models.common import maybe, rms_norm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(key, cfg, kind, dtype, *, moe_layer=False):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind in ("mamba", "mamba2"):
        init = init_mamba if kind == "mamba" else init_mamba2
        return {"ln": jnp.ones((d,), dtype), "mixer": init(ks[0], cfg, dtype)}
    p = {"ln1": jnp.ones((d,), dtype), "ln2": jnp.ones((d,), dtype)}
    if kind == "mla":
        p["attn"] = init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = init_attention(ks[0], cfg, dtype)
    if kind == "dec_attn":
        p["ln_cross"] = jnp.ones((d,), dtype)
        p["cross_attn"] = init_attention(ks[1], cfg, dtype, cross=True)
    if moe_layer:
        p["moe"] = init_moe(ks[2], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[3], cfg, dtype)
    return p


# ---------------------------------------------------------------------------
# adapter shape specs (consumed by core/adapters.py)
# ---------------------------------------------------------------------------

def target_shapes(cfg, kind, targets):
    """{nested param path: (d_in, d_out)} for the adapted modules of one
    block of the given kind."""
    d, H, Hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    out = {}
    if kind in ("mamba", "mamba2"):
        di = cfg.d_inner
        if kind == "mamba":
            shapes = {"in_proj": (d, 2 * di), "out_proj": (di, d),
                      "x_proj": (di, cfg.dt_rank + 2 * cfg.ssm.d_state),
                      "dt_proj": (cfg.dt_rank, di)}
        else:
            s = cfg.ssm
            nh = di // s.head_dim
            shapes = {"in_proj": (d, 2 * di + 2 * s.n_groups * s.d_state + nh),
                      "out_proj": (di, d)}
        wanted = [t for t in ("in_proj", "out_proj", "x_proj", "dt_proj")
                  if t in shapes and (t in targets or targets == ("wq", "wv"))]
        # default ("wq","wv") targets translate to (in_proj, out_proj) on SSMs
        if targets == ("wq", "wv"):
            wanted = ["in_proj", "out_proj"]
        for t in wanted:
            out[("mixer", t)] = shapes[t]
        return out
    if kind == "mla":
        m = cfg.mla
        remap = {"wq": ("wq_b", (m.q_lora_rank,
                                 H * (m.qk_nope_head_dim + m.qk_rope_head_dim))),
                 "wv": ("wkv_b", (m.kv_lora_rank,
                                  H * (m.qk_nope_head_dim + m.v_head_dim))),
                 "wk": ("wkv_a", (d, m.kv_lora_rank + m.qk_rope_head_dim)),
                 "wo": ("wo", (H * m.v_head_dim, d))}
        for t in targets:
            if t in remap:
                name, shape = remap[t]
                out[("attn", name)] = shape
        return out
    shapes = {"wq": (d, H * hd), "wk": (d, Hkv * hd), "wv": (d, Hkv * hd),
              "wo": (H * hd, d)}
    for t in targets:
        if t in shapes:
            out[("attn", t)] = shapes[t]
            if kind == "dec_attn":
                out[("cross_attn", t)] = shapes[t]
    return out


# ---------------------------------------------------------------------------
# forward (full sequence)
# ---------------------------------------------------------------------------

def block_forward(cfg, p, ad, acfg, x, positions, kind, *, window=None,
                  enc_out=None, vera_shared=None):
    """Returns (x, cache_entry, aux_loss)."""
    aux = 0.0
    if kind in ("mamba", "mamba2"):
        fwd = mamba_forward if kind == "mamba" else mamba2_forward
        y, h, conv = fwd(cfg, p["mixer"], maybe(ad, "mixer"), acfg,
                         rms_norm(x, p["ln"], cfg.norm_eps),
                         vera_shared=vera_shared)
        return x + y, {"h": h, "conv": conv}, aux
    h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "mla":
        y, (ckv, krope) = mla_forward(cfg, p["attn"], maybe(ad, "attn"), acfg,
                                      h_in, positions, vera_shared=vera_shared)
        cache = {"ckv": ckv, "krope": krope}
    else:
        causal = cfg.causal and kind != "enc_attn"
        y, (k, v) = attn_forward(cfg, p["attn"], maybe(ad, "attn"), acfg,
                                 h_in, positions, causal=causal,
                                 window=window, vera_shared=vera_shared)
        cache = {"k": k, "v": v}
    x = x + y
    if kind == "dec_attn":
        h_c = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        y, (ck, cv) = attn_forward(cfg, p["cross_attn"],
                                   maybe(ad, "cross_attn"), acfg, h_c,
                                   positions, causal=False, kv_x=enc_out,
                                   rope=False, vera_shared=vera_shared)
        cache.update({"cross_k": ck, "cross_v": cv})
        x = x + y
    h_mlp = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, aux = moe_forward(cfg, p["moe"], maybe(ad, "moe"), acfg, h_mlp,
                             vera_shared=vera_shared)
    else:
        y = mlp_forward(cfg, p["mlp"], maybe(ad, "mlp"), acfg, h_mlp,
                        vera_shared=vera_shared)
    return x + y, cache, aux


def block_prefill_suffix(cfg, p, ad, acfg, x, prefix_lens, cache, *,
                         block_tables, window=None, vera_shared=None):
    """Suffix-only prefill through one paged attn block.

    x: (B, L, d) hidden states of the divergent suffix; ``cache`` holds
    the segment's page pools with each row's PREFIX KV already resident
    via ``block_tables``. Only the ``attn`` kind exists here — the paged
    layout admits no other (``paged_unsupported_reason``). Returns
    (x, {"k", "v"}) with the suffix K/V (B, L, Hkv, hd) for the caller's
    post-scan scatter into private pages.
    """
    h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
    y, k, v = attn_prefill_suffix_paged(cfg, p["attn"], maybe(ad, "attn"),
                                        acfg, h_in, prefix_lens,
                                        cache["k"], cache["v"],
                                        block_tables, window=window,
                                        vera_shared=vera_shared)
    x = x + y
    h_mlp = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, _ = moe_forward(cfg, p["moe"], maybe(ad, "moe"), acfg, h_mlp,
                           vera_shared=vera_shared)
    else:
        y = mlp_forward(cfg, p["mlp"], maybe(ad, "mlp"), acfg, h_mlp,
                        vera_shared=vera_shared)
    return x + y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def block_decode(cfg, p, ad, acfg, x, pos, cache, kind, *, window=None,
                 vera_shared=None, paged=None):
    """x: (B, 1, d). Returns (x, new_cache_entry).

    ``paged`` (attn blocks only): {"block_tables": (B, P) int32,
    "attn_backend": "xla"|"pallas"} — the cache entry then holds page
    pools instead of per-row dense K/V (see ``attn_decode_paged``).
    """
    if kind in ("mamba", "mamba2"):
        step = mamba_step if kind == "mamba" else mamba2_step
        y, h, conv = step(cfg, p["mixer"], maybe(ad, "mixer"), acfg,
                          rms_norm(x, p["ln"], cfg.norm_eps),
                          cache["h"], cache["conv"], vera_shared=vera_shared)
        return x + y, {"h": h, "conv": conv}
    h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
    new_cache = dict(cache)
    if kind == "mla":
        y, ckv, krope = mla_decode(cfg, p["attn"], maybe(ad, "attn"), acfg,
                                   h_in, pos, cache["ckv"], cache["krope"],
                                   vera_shared=vera_shared)
        new_cache.update({"ckv": ckv, "krope": krope})
    elif paged is not None:
        y, k, v = attn_decode_paged(cfg, p["attn"], maybe(ad, "attn"), acfg,
                                    h_in, pos, cache["k"], cache["v"],
                                    paged["block_tables"], window=window,
                                    backend=paged["attn_backend"],
                                    vera_shared=vera_shared)
        new_cache.update({"k": k, "v": v})
    else:
        y, k, v = attn_decode(cfg, p["attn"], maybe(ad, "attn"), acfg, h_in,
                              pos, cache["k"], cache["v"], window=window,
                              vera_shared=vera_shared)
        new_cache.update({"k": k, "v": v})
    x = x + y
    if kind == "dec_attn":
        h_c = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        y = cross_attn_decode(cfg, p["cross_attn"], maybe(ad, "cross_attn"),
                              acfg, h_c, cache["cross_k"], cache["cross_v"],
                              vera_shared=vera_shared)
        x = x + y
    h_mlp = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, _ = moe_forward(cfg, p["moe"], maybe(ad, "moe"), acfg, h_mlp,
                           vera_shared=vera_shared)
    else:
        y = mlp_forward(cfg, p["mlp"], maybe(ad, "mlp"), acfg, h_mlp,
                        vera_shared=vera_shared)
    return x + y, new_cache
