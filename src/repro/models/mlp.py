"""Dense MLPs: SwiGLU (llama-style) and GELU (whisper/roberta-style)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import adapted, dense_init, maybe


def init_mlp(key, cfg, dtype, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu":
        return {"w_up": dense_init(ks[0], d, f, dtype),
                "w_down": dense_init(ks[1], f, d, dtype)}
    return {"w_gate": dense_init(ks[0], d, f, dtype),
            "w_up": dense_init(ks[1], d, f, dtype),
            "w_down": dense_init(ks[2], f, d, dtype)}


def mlp_forward(cfg, p, ad, acfg, x, *, vera_shared=None):
    sc = acfg.scaling if acfg is not None else 1.0
    vs = (vera_shared or {})
    if "w_gate" in p:
        g = adapted(p["w_gate"], maybe(ad, "w_gate"), x, sc, vs.get("w_gate"))
        u = adapted(p["w_up"], maybe(ad, "w_up"), x, sc, vs.get("w_up"))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        u = adapted(p["w_up"], maybe(ad, "w_up"), x, sc, vs.get("w_up"))
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    return adapted(p["w_down"], maybe(ad, "w_down"), h, sc, vs.get("w_down"))
