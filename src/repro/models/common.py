"""Shared building blocks: norms, RoPE, initializers, adapted linears.

Conventions
-----------
* Linear weights are stored **input-major**: ``w: (d_in, d_out)`` so the
  forward is ``x @ w`` with no transpose.
* LoRA adapters are stored transposed relative to the paper's notation:
  ``A: (d_in, r)`` (Gaussian init), ``B: (r, d_out)`` (zero init), so the
  paper's ``ΔW = B·A`` equals ``(A @ B)ᵀ`` here and the delta activation is
  ``(x @ A) @ B * scaling``.
* VeRA adapters hold trainable vectors ``d: (r,)`` (the paper's Λ_d / "A_d",
  aggregated under FedSA) and ``b: (d_out,)`` (Λ_b / "B_b", kept local); the
  frozen random matrices live once per target-module name in
  ``adapters["vera_shared"]``.
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

# Grouped multi-tenant LoRA backend: "jnp" (gather + einsum, the default),
# "bgmv" (fused repro.kernels.bgmv base+delta matmul; needs the
# batch-global Ā), or "sgmv" (fused repro.kernels.sgmv with BOTH matrices
# per row — personal-A adapters and mixed fleets; uses bgmv as the fast
# path whenever the gathered A turns out batch-global). Trace-scoped via
# ``grouped_lora_backend`` — the serving engine enters the context inside
# its jitted step so the choice is baked at trace time per engine.
_GROUPED_LORA_BACKEND = ["jnp"]


@contextlib.contextmanager
def grouped_lora_backend(name):
    prev = _GROUPED_LORA_BACKEND[0]
    _GROUPED_LORA_BACKEND[0] = name
    try:
        yield
    finally:
        _GROUPED_LORA_BACKEND[0] = prev


def rms_norm(x, gamma, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, gamma, beta, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab, d, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Adapted linear: base matmul + optional LoRA/rsLoRA/VeRA delta.
# ---------------------------------------------------------------------------

def lora_delta(ad, x, scaling, vera_shared=None):
    """Low-rank delta activation for one linear.

    ``ad`` is either a LoRA leaf ``{"A","B"}`` or a VeRA leaf ``{"d","b"}``
    (with the shared frozen matrices passed via ``vera_shared``).
    """
    if "d" in ad:  # VeRA
        A = vera_shared["A"]  # (d_in, r) frozen
        B = vera_shared["B"]  # (r, d_out) frozen
        h = x.astype(jnp.float32) @ A.astype(jnp.float32)
        h = h * ad["d"].astype(jnp.float32)
        h = h @ B.astype(jnp.float32)
        return (h * ad["b"].astype(jnp.float32)).astype(x.dtype)
    # Grouped multi-tenant serving (repro.serving): a 3-D B is one B_i per
    # batch row, gathered from the registry slot table; under FedSA the
    # aggregated Ā stays batch-global (2-D) so x @ A computes once for
    # the batch.
    A = ad["A"].astype(jnp.float32)
    if A.ndim == 3 and x.ndim == 3:
        # Generic per-row A_i — the SGMV shrink: personal-A adapters
        # (FedIT plain LoRA / FedDPA personal pairs, packed into A slot
        # tables by the registry) and the version-indexed gather of a
        # double-buffered registry (repro.serving.refresh) both hand one
        # A per batch row, so the rank-r projection runs as a batched
        # matmul and one decode batch can mix tenants whose A's differ
        # (or rows admitted under different federation rounds).
        h = jnp.einsum("bsd,bdr->bsr", x.astype(jnp.float32), A)
    else:
        h = x.astype(jnp.float32) @ A
    B = ad["B"].astype(jnp.float32)
    if B.ndim == 3 and x.ndim == 3:
        h = jnp.einsum("bsr,brn->bsn", h, B)
    else:
        h = h @ B
    return (h * scaling).astype(x.dtype)


def adapted(w, ad, x, scaling, vera_shared=None):
    """``x @ w`` plus the adapter delta when ``ad`` is present.

    The base weight never receives gradients (LoRA semantics): it is wrapped
    in ``stop_gradient`` here so callers can simply differentiate w.r.t. the
    adapter pytree.
    """
    backend = _GROUPED_LORA_BACKEND[0]
    if (backend in ("bgmv", "sgmv") and ad is not None
            and "B" in ad and getattr(ad["B"], "ndim", 0) == 3
            and x.ndim == 3 and x.shape[1] == 1):
        # Grouped decode on the fused kernels. ad["A"]/ad["B"] are already
        # the per-row gather, so the slot table handed to the kernel is
        # the batch itself with identity slot ids.
        a_ndim = getattr(ad.get("A"), "ndim", 0)
        from repro.kernels import ops as kops
        if a_ndim == 2:
            # batch-global Ā (the FedSA invariant): the bgmv fast path —
            # one shared shrink per tile — is legal under BOTH backend
            # names, so an sgmv engine serving a pure-FedSA batch pays
            # nothing for the generality
            y = kops.bgmv(x[:, 0], jax.lax.stop_gradient(w), ad["A"],
                          ad["B"], jnp.arange(x.shape[0], dtype=jnp.int32),
                          scaling)
            return y[:, None]
        if a_ndim == 3 and backend == "sgmv":
            # per-row A_i (personal-A adapters, or the version-indexed
            # gather of a double-buffered registry): generic SGMV
            y = kops.sgmv(x[:, 0], jax.lax.stop_gradient(w), ad["A"],
                          ad["B"], jnp.arange(x.shape[0], dtype=jnp.int32),
                          scaling)
            return y[:, None]
        # backend == "bgmv" with a per-row 3-D A: the shared-Ā kernel
        # cannot express it — fall through to the grouped jnp path
    y = x @ jax.lax.stop_gradient(w)
    if ad is not None:
        if "global" in ad:  # FedDPA: sum of global + personal adapters
            y = y + lora_delta(ad["global"], x, scaling, vera_shared)
            y = y + lora_delta(ad["personal"], x, scaling, vera_shared)
        else:
            y = y + lora_delta(ad, x, scaling, vera_shared)
    return y


def effective_weight(w, ad, scaling, vera_shared=None):
    """Materialize ``W + ΔW`` for one linear (decode paths that transform
    *cached* activations need the merged weight, e.g. MLA's wkv_b)."""
    if ad is None:
        return w
    def one_delta(leaf):
        if "d" in leaf:  # VeRA: ΔW = (A·diag(d))·B·diag(b)
            A = vera_shared["A"].astype(jnp.float32)
            B = vera_shared["B"].astype(jnp.float32)
            return ((A * leaf["d"].astype(jnp.float32)) @ B
                    * leaf["b"].astype(jnp.float32)[None, :])
        return (leaf["A"].astype(jnp.float32)
                @ leaf["B"].astype(jnp.float32)) * scaling
    if "global" in ad:   # FedDPA
        delta = one_delta(ad["global"]) + one_delta(ad["personal"])
    else:
        delta = one_delta(ad)
    return (w.astype(jnp.float32) + delta).astype(w.dtype)


def maybe(ad, name):
    """adapters subtree lookup that tolerates missing modules."""
    if ad is None:
        return None
    return ad.get(name)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, theta):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta):
    """x: (B, S, H, hd) or (B, S, hd); positions: (S,) or (B, S) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv   # (..., S, hd/2)
    if positions.ndim == 1:
        ang = ang[None]                               # (1, S, hd/2)
    if x.ndim == 4:
        ang = ang[:, :, None, :]                      # add head axis
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked cross-entropy (never materializes (B, S, V) logits).
# ---------------------------------------------------------------------------

def chunked_cross_entropy(hidden, w_head, labels, mask=None, chunk=512):
    """Mean CE of ``softmax(hidden @ w_head)`` vs labels, scanned over seq.

    hidden: (B, S, d); w_head: (d, V); labels: (B, S) int32;
    mask: (B, S) float or None (1 = count).
    """
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)

    def chunk_loss(h, y, m):
        logits = (h @ w_head).astype(jnp.float32)            # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * m), jnp.sum(m)

    def body(carry, args):
        tot, cnt = carry
        l, c = chunk_loss(*args)
        return (tot + l, cnt + c), None

    hs = hidden[:, : n * chunk].reshape(B, n, chunk, d).swapaxes(0, 1)
    ys = labels[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
    ms = mask[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (hs, ys, ms))
    if rem:
        l, c = chunk_loss(hidden[:, n * chunk:], labels[:, n * chunk:],
                          mask[:, n * chunk:])
        tot, cnt = tot + l, cnt + c
    return tot / jnp.maximum(cnt, 1.0)
