"""Mamba1 (selective scan) — Falcon-Mamba block.

Training/prefill uses a chunked scan: sequential ``lax.scan`` over chunks
carrying the ``(d_inner, d_state)`` state, associative scan inside each chunk
(bounds the O(S·d_inner·d_state) element memory to one chunk). Decode is the
single-step recurrence with a conv ring buffer. Tensor parallelism shards
``d_inner``; the scan is elementwise over it, so no collectives occur inside
the recurrence (Mamba-TP layout).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import adapted, dense_init, maybe


def init_mamba(key, cfg, dtype):
    s = cfg.ssm
    d, di, dtr = cfg.d_model, cfg.d_inner, cfg.dt_rank
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, di), jnp.float32)
                   * s.d_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * s.d_state, dtype),
        "dt_proj": dense_init(ks[3], dtr, di, dtype, scale=dtr ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (di,), jnp.float32)
                     * (0.1 - 1e-3) + 1e-3, 1e-4, None))).astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d, dtype),
    }


def causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, S, C); w: (k, C); b: (C,)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    S = x.shape[1]
    out = sum(xp[:, j:j + S] * w[j] for j in range(k))
    return out + b


def conv_step(x_t, buf, w, b):
    """x_t: (B, C); buf: (B, k-1, C) past inputs. Returns (y, new_buf)."""
    win = jnp.concatenate([buf, x_t[:, None]], axis=1)     # (B, k, C)
    y = jnp.einsum("bkc,kc->bc", win, w) + b
    return y, win[:, 1:]


def _assoc_scan_chunk(a, b):
    """Associative scan of h_t = a_t h_{t-1} + b_t over axis 1 (time)."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2
    return jax.lax.associative_scan(combine, (a, b), axis=1)


def selective_scan(dt, xc, Bc, C, A, chunk):
    """Fused chunked selective scan (kernel-shaped, §Perf iteration 1).

    h_t = exp(dt_t A)⊙h_{t-1} + (dt_t·x_t)⊗B_t ; y_t = Σ_s h_t[·,s]·C_t[·,s]

    dt, xc: (B, S, di); Bc, C: (B, S, ds); A: (di, ds). The rank-4
    (B, S, di, ds) decay/input tensors are NEVER materialized for the full
    sequence — they are computed per chunk inside the scan body (the same
    fusion the Pallas `kernels/ssm_scan.py` performs with VMEM-resident
    state on TPU). Returns y (B, S, di) f32 and final state (B, di, ds).
    """
    B, S, di = dt.shape
    ds = Bc.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    n = (S + pad) // chunk
    dtc = dt.reshape(B, n, chunk, di).swapaxes(0, 1)
    xcc = xc.reshape(B, n, chunk, di).swapaxes(0, 1)
    Bcc = Bc.reshape(B, n, chunk, ds).swapaxes(0, 1)
    Cc = C.reshape(B, n, chunk, ds).swapaxes(0, 1)

    def body(h, inp):
        dti, xi, Bi, Ci = inp                              # per-chunk slices
        ai = jnp.exp(dti[..., None] * A)                   # (B, c, di, ds)
        bi = (dti * xi)[..., None] * Bi[..., None, :]
        acum, bcum = _assoc_scan_chunk(ai, bi)             # prefix products
        h_all = acum * h[:, None] + bcum                   # (B, c, di, ds)
        y = jnp.einsum("bcds,bcs->bcd", h_all, Ci)
        return h_all[:, -1], y

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    h_fin, ys = jax.lax.scan(body, h0, (dtc, xcc, Bcc, Cc))
    y = ys.swapaxes(0, 1).reshape(B, S + pad, di)[:, :S]
    return y, h_fin


def _ssm_inputs(cfg, p, xc):
    """Pre-scan projections. xc: (B, S, di) conv output (f32 math).

    Returns the RANK-3 scan inputs (dt, Bc, Cc) and A — the rank-4
    decay/input tensors are formed per chunk inside ``selective_scan``.
    """
    s, dtr = cfg.ssm, cfg.dt_rank
    proj = xc @ p["x_proj"]
    dt, Bc, Cc = jnp.split(proj.astype(jnp.float32),
                           [dtr, dtr + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"])                   # (B, S, di)
    A = -jnp.exp(p["A_log"])                               # (di, ds)
    return dt, Bc, Cc, A


def mamba_forward(cfg, p, ad, acfg, x, *, vera_shared=None):
    """Full-sequence Mamba1. x: (B, S, d) → (y, final_state, conv_tail)."""
    s = cfg.ssm
    sc = acfg.scaling if acfg is not None else 1.0
    vs = (vera_shared or {})
    xz = adapted(p["in_proj"], maybe(ad, "in_proj"), x, sc, vs.get("in_proj"))
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc = causal_conv(x_in, jax.lax.stop_gradient(p["conv_w"]),
                     jax.lax.stop_gradient(p["conv_b"]))
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    dt, Bc, Cc, A = _ssm_inputs(cfg, p, xc)
    if s.backend == "pallas":
        # production TPU path: fully-fused Pallas kernel, VMEM-resident
        # state (kernels/ssm_scan.py); validated vs selective_scan in tests
        from repro.kernels import ops as kops
        y, h = kops.ssm_scan_fused(dt, xc.astype(jnp.float32), Bc, Cc, A,
                                   bd=min(512, dt.shape[-1]),
                                   chunk=min(s.chunk, dt.shape[1]))
    else:
        y, h = selective_scan(dt, xc.astype(jnp.float32), Bc, Cc, A, s.chunk)
    y = y + p["D"] * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = adapted(p["out_proj"], maybe(ad, "out_proj"), y.astype(x.dtype), sc,
                vs.get("out_proj"))
    conv_tail = x_in[:, -(s.d_conv - 1):]                   # decode warm-start
    return y, h, conv_tail


def mamba_step(cfg, p, ad, acfg, x, h, conv_buf, *, vera_shared=None):
    """One decode step. x: (B, 1, d); h: (B, di, ds); conv_buf: (B, k-1, di)."""
    sc = acfg.scaling if acfg is not None else 1.0
    vs = (vera_shared or {})
    xz = adapted(p["in_proj"], maybe(ad, "in_proj"), x[:, 0], sc,
                 vs.get("in_proj"))
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc, conv_buf = conv_step(x_in, conv_buf, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    dt, Bc, Cc, A = _ssm_inputs(cfg, p, xc[:, None])
    a = jnp.exp(dt[:, 0, :, None] * A)                      # (B, di, ds)
    b = (dt[:, 0] * xc.astype(jnp.float32))[..., None] * Bc[:, 0, None, :]
    h = a * h + b                                           # (B, di, ds)
    y = jnp.einsum("bds,bs->bd", h, Cc[:, 0])
    y = y + p["D"] * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = adapted(p["out_proj"], maybe(ad, "out_proj"), y.astype(x.dtype), sc,
                vs.get("out_proj"))
    return y[:, None], h, conv_buf
