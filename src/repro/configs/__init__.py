"""Config registry: ``get_config(name)`` / ``--arch <id>``."""
from repro.configs import (
    chameleon_34b,
    deepseek_7b,
    deepseek_v3_671b,
    falcon_mamba_7b,
    granite_moe_3b,
    minitron_4b,
    qwen3_32b,
    roberta_large,
    stablelm_3b,
    whisper_tiny,
    zamba2_2p7b,
)
from repro.configs.base import (
    AdapterConfig,
    FedConfig,
    InputShape,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    reduced,
)
from repro.configs.shapes import SHAPES, get_shape

# The 10 assigned architectures (dry-run matrix) ...
ASSIGNED = {
    m.CONFIG.name: m.CONFIG
    for m in (
        chameleon_34b,
        falcon_mamba_7b,
        deepseek_7b,
        qwen3_32b,
        granite_moe_3b,
        deepseek_v3_671b,
        zamba2_2p7b,
        stablelm_3b,
        minitron_4b,
        whisper_tiny,
    )
}
# ... plus the paper's own backbone.
REGISTRY = dict(ASSIGNED)
REGISTRY[roberta_large.CONFIG.name] = roberta_large.CONFIG


def get_config(name: str) -> ModelConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(REGISTRY)}") from None


def list_archs():
    return sorted(ASSIGNED)


__all__ = [
    "ASSIGNED", "REGISTRY", "SHAPES", "AdapterConfig", "FedConfig",
    "InputShape", "MLAConfig", "ModelConfig", "MoEConfig", "SSMConfig",
    "get_config", "get_shape", "list_archs", "reduced",
]
