"""DeepSeek-7B — LLaMA-architecture dense model [arXiv:2401.02954]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,         # MHA (GQA with kv == heads)
    d_ff=11008,
    vocab_size=102400,
    rope_theta=10_000.0,
    sliding_window=16_384,  # long_500k variant only
    source="arXiv:2401.02954",
)
