"""Granite-MoE-3B-A800M — 40 routed experts, top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base family].

The assignment line specifies "MoE 40e top-8" (the bracket note says 32; we
follow the explicit config line: 40 experts).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,              # (== per-expert d_ff; all MLPs are MoE)
    vocab_size=49155,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff=512, capacity_factor=1.25),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
