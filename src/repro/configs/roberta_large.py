"""RoBERTa-large — the paper's own NLU backbone (Liu et al., 2019).

Used by the paper-claims benchmarks (at reduced size on CPU); implemented as
a bidirectional encoder + classification head. Not part of the assigned
10-arch pool, so it is exercised by benchmarks/tests rather than the dry-run
matrix.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="roberta-large",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=50265,
    act="gelu",
    causal=False,

    source="arXiv:1907.11692",
)
