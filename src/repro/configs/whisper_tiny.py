"""Whisper-tiny — encoder-decoder; conv/mel frontend stubbed
[arXiv:2212.04356].

``input_specs`` feeds precomputed frame embeddings ``(B, 1500, 384)`` — the
allowed frontend carve-out. n_layers counts decoder layers; the encoder has
the same depth. Positional encoding uses RoPE in this implementation
(deviation from Whisper's sinusoidal/learned embeddings, noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    act="gelu",
    enc_dec=True,
    n_enc_layers=4,
    enc_seq=1500,
    source="arXiv:2212.04356",
)
