"""Zamba2-2.7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

One attention block's weights are shared across all its occurrences (every
6th layer); each occurrence applies its own LoRA delta, mirroring the real
model's shared-block-plus-LoRA design.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(version=2, d_state=64, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk=256),
    attn_every=6,          # layers 5, 11, ... are the shared attention block
    source="arXiv:2411.15242",
)
