"""Architecture + run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``. The model zoo
in ``repro.models`` consumes only this dataclass, so new architectures are
added by writing one config file in this package.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0              # routed experts
    n_shared_experts: int = 0       # always-on experts (DeepSeek-V3)
    top_k: int = 2
    d_ff: int = 0                   # per-expert hidden size
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # layers whose MLP is dense instead of MoE (DeepSeek-V3: first 3)
    n_dense_layers: int = 0
    # explicit shard_map expert-parallel dispatch (§Perf it. 2f). Compiles
    # and produces the intended all-to-all schedule, but on THIS XLA-CPU
    # toolchain the vmap/auto-axes boundary inserts extra gathers — left
    # opt-in pending Shardy/TPU validation (see EXPERIMENTS.md §Perf).
    expert_parallel: bool = False


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    absorbed_decode: bool = False   # §Perf optimization (fold W_UK into q)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    version: int = 1                # 1 = Mamba1 selective scan, 2 = Mamba2 SSD
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 -> ceil(d_model / 16)
    head_dim: int = 64              # Mamba2 only
    n_groups: int = 1               # Mamba2 only
    chunk: int = 128                # scan chunk length
    backend: str = "xla"            # "xla" (chunked lax.scan) | "pallas"
                                    # (fused VMEM-resident kernel, TPU)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"               # silu (SwiGLU) | gelu (plain MLP)
    causal: bool = True             # False -> bidirectional encoder (RoBERTa)
    # sliding-window attention (None = full causal). Used for long_500k on
    # otherwise-full-attention architectures (see DESIGN.md §5).
    sliding_window: Optional[int] = None
    # "xla" = blockwise lax.scan attention; "pallas" = flash kernel
    # (kernels/flash_attention.py, TPU target; interpret-mode on CPU).
    attn_backend: str = "xla"
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (Zamba2): one shared attention block every `attn_every` layers;
    # the attention block's weights are shared across occurrences.
    attn_every: int = 0
    # encoder-decoder (Whisper): n_layers counts decoder layers.
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500             # stub frontend frame count
    # multi-token prediction depth (DeepSeek-V3)
    mtp_depth: int = 0
    # source citation for the assigned config
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        assert self.ssm is not None
        return self.ssm.dt_rank or -(-self.d_model // 16)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kinds(self) -> Tuple[str, ...]:
        """Block kind per layer index ("attn", "mamba", "mamba2")."""
        if self.family == "ssm":
            kind = "mamba" if self.ssm.version == 1 else "mamba2"
            return (kind,) * self.n_layers
        if self.family == "hybrid":
            kinds = []
            for i in range(self.n_layers):
                if self.attn_every and (i % self.attn_every) == (self.attn_every - 1):
                    kinds.append("attn")
                else:
                    kinds.append("mamba2" if self.ssm.version == 2 else "mamba")
            return tuple(kinds)
        return ("attn",) * self.n_layers


@dataclasses.dataclass(frozen=True)
class AdapterConfig:
    """The paper's technique: LoRA variant × federated aggregation mode."""
    variant: str = "lora"           # lora | rslora | vera
    mode: str = "fedsa"             # fedavg | ffa | fedsa | fedit | feddpa
    rank: int = 8
    alpha: float = 16.0
    vera_rank: int = 256
    vera_d_init: float = 0.1
    # which module names receive adapters; default follows the paper
    # (q/v attention projections). SSM archs override (DESIGN.md §4).
    target_modules: Tuple[str, ...] = ("wq", "wv")
    dropout: float = 0.0

    @property
    def scaling(self) -> float:
        import math
        if self.variant == "rslora":
            return self.alpha / math.sqrt(self.rank)
        if self.variant == "vera":
            return 1.0
        return self.alpha / self.rank


@dataclasses.dataclass(frozen=True)
class FedConfig:
    n_clients: int = 3
    local_steps: int = 10           # E in the paper
    rounds: int = 100
    client_sample_rate: float = 1.0
    dirichlet_alpha: Optional[float] = 0.5   # None -> IID
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


def reduced(cfg: ModelConfig, n_layers: int = 2, d_model: int = 256,
            n_experts: int = 4) -> ModelConfig:
    """A CPU-smoke-test-sized variant of the same architecture family."""
    n_heads = max(2, min(cfg.n_heads, 4))
    ratio = max(1, cfg.n_heads // max(1, cfg.n_kv_heads))
    n_kv = max(1, n_heads // min(ratio, n_heads))
    head_dim = max(8, d_model // n_heads)
    d_model = head_dim * n_heads
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, n_experts),
            top_k=min(cfg.moe.top_k, 2), d_ff=max(32, d_model // 2),
            n_dense_layers=min(cfg.moe.n_dense_layers, 1))
    mla = None
    if cfg.mla is not None:
        mla = MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                        qk_rope_head_dim=8, v_head_dim=16)
        head_dim = 0
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, d_state=min(cfg.ssm.d_state, 16),
                                  head_dim=16, chunk=16, dt_rank=8)
    return dataclasses.replace(
        cfg, name=cfg.name + "-reduced", n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=n_kv, head_dim=head_dim,
        d_ff=max(64, d_model * 2), vocab_size=min(cfg.vocab_size, 512),
        moe=moe, mla=mla, ssm=ssm,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2), enc_seq=16,
        mtp_depth=min(cfg.mtp_depth, 1))
