"""Qwen3-32B — dense GQA with qk_norm [hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,          # Qwen3 decouples head_dim from d_model/n_heads
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    sliding_window=16_384,  # long_500k variant only
    source="hf:Qwen/Qwen3-8B",
)
