"""Minitron-4B — pruned Nemotron, 256k vocab [arXiv:2407.14679]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    rope_theta=10_000.0,
    sliding_window=16_384,  # long_500k variant only
    source="arXiv:2407.14679",
)
