"""Chameleon-34B — early-fusion VLM trunk [arXiv:2405.09818].

Images enter as VQ token ids in the shared 65536 vocab; the VQ image
tokenizer is the stubbed modality frontend (DESIGN.md §3.3).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,          # Chameleon uses qk-norm for training stability
    rope_theta=10_000.0,
    sliding_window=16_384,  # enabled only for the long_500k variant
    source="arXiv:2405.09818",
)
