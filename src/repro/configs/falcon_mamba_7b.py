"""Falcon-Mamba-7B — attention-free Mamba1 [arXiv:2410.05355]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,             # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,                # no MLP: Mamba block replaces attn+MLP
    vocab_size=65024,
    ssm=SSMConfig(version=1, d_state=16, d_conv=4, expand=2, chunk=256),
    source="arXiv:2410.05355",
)
