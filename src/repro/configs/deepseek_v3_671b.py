"""DeepSeek-V3-671B — MLA + 1 shared / 256 routed top-8 MoE + MTP
[arXiv:2412.19437]."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,        # MLA: kv heads == q heads (cache is latent)
    d_ff=18432,            # dense-MLP layers (first 3)
    vocab_size=129280,
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=256, n_shared_experts=1, top_k=8, d_ff=2048,
                  capacity_factor=1.25, n_dense_layers=3),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    mtp_depth=1,
    source="arXiv:2412.19437",
)
