"""Pure-JAX optimizers with per-leaf trainability masks.

The paper uses SGD for LoRA/rsLoRA and AdamW for VeRA. Optimizers are
(init, update) pairs over arbitrary pytrees; a ``mask`` pytree of 0/1
scalars (from ``core.strategies.trainable_mask``) zeroes updates of frozen
leaves so FFA's fixed A (and VeRA's frozen shared matrices) never move.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _masked(updates, mask):
    if mask is None:
        return updates
    return jax.tree_util.tree_map(lambda u, m: u * m, updates, mask)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def sgd(lr, momentum=0.0):
    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params=None, mask=None, step=None):
        lr_t = lr(step) if callable(lr) else lr
        if momentum == 0.0:
            upd = jax.tree_util.tree_map(
                lambda g: -lr_t * g.astype(jnp.float32), grads)
            return _masked(upd, mask), state
        mu = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            state["mu"], grads)
        upd = jax.tree_util.tree_map(lambda m: -lr_t * m, mu)
        return _masked(upd, mask), {"mu": mu}

    return init, update


def adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0):
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None, mask=None, step=None):
        t = state["t"] + 1
        lr_t = lr(t) if callable(lr) else lr
        m = jax.tree_util.tree_map(
            lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda vv, g: b2 * vv + (1 - b2)
            * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        c1 = 1 - b1 ** t.astype(jnp.float32)
        c2 = 1 - b2 ** t.astype(jnp.float32)

        def leaf_update(mm, vv, p):
            upd = -(lr_t * (mm / c1) / (jnp.sqrt(vv / c2) + eps))
            if weight_decay and p is not None:
                upd = upd - lr_t * weight_decay * p.astype(jnp.float32)
            return upd

        if params is None:
            upd = jax.tree_util.tree_map(
                lambda mm, vv: leaf_update(mm, vv, None), m, v)
        else:
            upd = jax.tree_util.tree_map(leaf_update, m, v, params)
        return _masked(upd, mask), {"m": m, "v": v, "t": t}

    return init, update
