from repro.optim.optimizers import adamw, apply_updates, sgd
from repro.optim.schedules import constant, cosine, linear_warmup

__all__ = ["adamw", "apply_updates", "sgd", "constant", "cosine",
           "linear_warmup"]
