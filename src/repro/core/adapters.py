"""Adapter parameter trees for LoRA / rsLoRA / VeRA across the model zoo.

The adapter pytree mirrors the model's segment layout so the same
``lax.scan`` consumes (params, adapters) in lockstep:

```
adapters = {
  "segments": [seg0, seg1, ...],     # stacked (n_layers_in_seg, ...)
  "enc":      {"segments": [...]}    # enc-dec only
  "vera_shared": {module: {"A","B"}} # VeRA only: frozen random matrices
}
```

Each adapted module holds one *leaf dict*:
  lora/rslora      {"A": (d_in, r) gaussian, "B": (r, d_out) zeros}
  vera             {"d": (r,) = d_init,      "b": (d_out,) zeros}
  feddpa           {"global": leaf, "personal": leaf}   (dual adapters)

Which leaves are aggregated / kept local / frozen is decided by
``core.strategies`` — the adapter tree itself is mode-agnostic except for
FedDPA's doubled structure.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.blocks import target_shapes
from repro.models.transformer import segments


def _lora_leaf(key, d_in, d_out, rank, dtype):
    return {"A": (jax.random.normal(key, (d_in, rank), jnp.float32)
                  * d_in ** -0.5).astype(dtype),
            "B": jnp.zeros((rank, d_out), dtype)}


def _vera_leaf(key, d_in, d_out, rank, d_init, dtype):
    del key
    return {"d": jnp.full((rank,), d_init, dtype),
            "b": jnp.zeros((d_out,), dtype)}


def _module_leaf(key, shape, acfg, dtype):
    d_in, d_out = shape
    if acfg.variant == "vera":
        return _vera_leaf(key, d_in, d_out, acfg.vera_rank,
                          acfg.vera_d_init, dtype)
    leaf = functools.partial(_lora_leaf, d_in=d_in, d_out=d_out,
                             rank=acfg.rank, dtype=dtype)
    if acfg.mode == "feddpa":
        k1, k2 = jax.random.split(key)
        return {"global": leaf(k1), "personal": leaf(k2)}
    return leaf(key)


def _block_adapters(key, cfg, kind, acfg, dtype):
    """Nested adapter dict for ONE block of the given kind."""
    shapes = target_shapes(cfg, kind, acfg.target_modules)
    out = {}
    ks = jax.random.split(key, max(1, len(shapes)))
    for k, (path, shape) in zip(ks, sorted(shapes.items())):
        group, name = path
        out.setdefault(group, {})[name] = _module_leaf(k, shape, acfg, dtype)
    return out


def _stack(key, n, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_adapters(key, cfg, acfg, dtype=jnp.float32):
    segs = segments(cfg)
    ks = jax.random.split(key, len(segs) + 2)
    out = {"segments": []}
    for seg, sk in zip(segs, ks[:-2]):
        if seg["kind"] == "hybrid":
            k1, k2 = jax.random.split(sk)
            out["segments"].append({
                "mamba": _stack(k1, seg["n"], lambda k: _stack(
                    k, seg["inner"],
                    lambda kk: _block_adapters(kk, cfg, "mamba2", acfg,
                                               dtype))),
                "attn": _stack(k2, seg["n"],
                               lambda k: _block_adapters(k, cfg, "attn",
                                                         acfg, dtype)),
            })
        else:
            out["segments"].append(_stack(
                sk, seg["n"],
                lambda k: _block_adapters(k, cfg, seg["kind"], acfg, dtype)))
    if cfg.enc_dec:
        out["enc"] = {"segments": [_stack(
            ks[-2], cfg.n_enc_layers,
            lambda k: _block_adapters(k, cfg, "enc_attn", acfg, dtype))]}
    if acfg.variant == "vera":
        out["vera_shared"] = _init_vera_shared(ks[-1], cfg, acfg, dtype)
    return out


def _init_vera_shared(key, cfg, acfg, dtype):
    """One frozen (A, B) pair per adapted module name, shared across layers
    (VeRA's defining trait). Kaiming-uniform init, per the paper."""
    shapes = {}
    kinds = {seg["kind"] for seg in segments(cfg)}
    if "hybrid" in kinds:
        kinds = (kinds - {"hybrid"}) | {"mamba2", "attn"}
    if cfg.enc_dec:
        kinds.add("enc_attn")
    for kind in sorted(kinds):
        for (group, name), shape in target_shapes(
                cfg, kind, acfg.target_modules).items():
            prev = shapes.get(name)
            if prev is None or (shape[0] * shape[1] > prev[0] * prev[1]):
                shapes[name] = shape
    out = {}
    ks = jax.random.split(key, max(1, len(shapes)))
    r = acfg.vera_rank
    for k, (name, (d_in, d_out)) in zip(ks, sorted(shapes.items())):
        k1, k2 = jax.random.split(k)
        lim_a = (6.0 / d_in) ** 0.5
        lim_b = (6.0 / r) ** 0.5
        out[name] = {
            "A": jax.random.uniform(k1, (d_in, r), dtype, -lim_a, lim_a),
            "B": jax.random.uniform(k2, (r, d_out), dtype, -lim_b, lim_b),
        }
    return out


# ---------------------------------------------------------------------------
# Introspection helpers
# ---------------------------------------------------------------------------

def n_params(tree):
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def leaf_paths(tree):
    """[(path_string, leaf)] with '/'-joined dict keys and seq indices."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            else:
                parts.append(str(p.idx))
        out.append(("/".join(parts), leaf))
    return out
