"""FetchSGD-style count-sketch compression of the aggregated A-updates.

Appendix A.7 of the paper: since the per-round A-updates are "trivial but
necessary", they compress to ~50% with a count sketch without hurting
accuracy. Clients sketch their A-*deltas*, the server sums the sketches
(sketching is linear, so sum-of-sketches = sketch-of-sum), unsketches with
the median estimator, and keeps the top-k coordinates.

The sketch state (hash indices and signs) is derived deterministically from
a seed so server and clients agree without communicating it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_sketch(seed, dim, rows=5, compression=0.5):
    """Hash state for a (rows × cols) count sketch of a dim-vector.

    ``compression`` = sketch_size / dim: cols = compression·dim / rows.
    """
    cols = max(1, int(dim * compression / rows))
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    idx = jax.random.randint(k1, (rows, dim), 0, cols)
    sign = jax.random.rademacher(k2, (rows, dim), jnp.float32)
    return {"idx": idx, "sign": sign, "rows": rows, "cols": cols, "dim": dim}


def sketch(state, g):
    """g: (dim,) → table (rows, cols)."""
    rows, cols = state["rows"], state["cols"]

    def one_row(idx_r, sign_r):
        return jnp.zeros((cols,), jnp.float32).at[idx_r].add(
            sign_r * g.astype(jnp.float32))

    return jax.vmap(one_row)(state["idx"], state["sign"])


def unsketch(state, table, topk_frac=0.5):
    """Median-of-rows estimate, then keep the top-k largest coordinates."""
    est = jnp.median(state["sign"] * table[jnp.arange(state["rows"])[:, None],
                                           state["idx"]], axis=0)
    k = max(1, int(state["dim"] * topk_frac))
    thresh = jnp.sort(jnp.abs(est))[-k]
    return jnp.where(jnp.abs(est) >= thresh, est, 0.0)


def compress_roundtrip(state, g, topk_frac=0.5):
    """sketch→unsketch of one vector (what one FL round does to ΔA)."""
    return unsketch(state, sketch(state, g), topk_frac)


def sketch_tree_size(tree_leaf_sizes, compression=0.5):
    """Communicated parameter count under sketching (Table 10 column)."""
    return int(sum(tree_leaf_sizes) * compression)
