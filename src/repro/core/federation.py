"""Host federated runtime: vmap over clients, scan over local steps.

This is the runtime behind every accuracy experiment (paper Tables 1, 3, 4,
5, 10 and Fig. 2). Clients are a leading pytree axis; one communication
round is a single jitted call:

  round = vmap_over_clients( scan(E local SGD steps) ) ∘ selective_aggregate

The *in-mesh* (TPU pod) counterpart of the same round lives in
``repro.launch.train``; this module is the CPU-scale reference semantics.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adapters import init_adapters
from repro.core.aggregation import (aggregate, broadcast_clients,
                                    corrupt_shared, scale_shared,
                                    shared_client_stats, take_shared)
from repro.core.strategies import count_params, trainable_mask
from repro.data.synthetic import stack_client_batch
from repro.models.transformer import (classifier_loss, encode_logits,
                                      init_classifier, init_model, loss_fn)
from repro.optim import adamw, apply_updates, sgd


@dataclasses.dataclass
class FedSystem:
    cfg: object
    acfg: object
    fed: object
    params: object              # frozen base model (no client axis)
    trainables: object          # client-axis adapter (+head) tree
    opt_state: object
    mask: object
    round_fn: object            # jitted (trainables, opt_state, batches, part)
    eval_fn: object
    comm_per_round: int         # parameters uploaded per client per round
    n_trainable: int
    update_fn: object = None    # jitted client updates WITHOUT aggregation
    agg_fn: object = None       # jitted (tr, contribute, receive, trim)


@dataclasses.dataclass(frozen=True)
class RobustConfig:
    """Knobs of the fault-tolerant round path (``run_rounds(robust=)``).

    The defaults are deliberately permissive — the gate only ever
    *rejects* provably-poisonous updates (non-finite) and *clips* norm
    outliers, so a fault-free run under ``RobustConfig()`` aggregates
    exactly like the plain path.
    """
    round_deadline_s: float = None   # straggler cutoff (simulated delay
    #                                  budget per round; None = no cutoff)
    max_retries: int = 1             # bounded retries for a failed update
    backoff_s: float = 0.05          # simulated backoff per retry attempt
    reject_nonfinite: bool = True    # NaN/Inf shared updates are rejected
    outlier_mult: float = 6.0        # clip ‖update‖ to mult × median;
    #                                  None disables clipping
    trim: float = 0.0                # trimmed-mean fraction (0 = mean)


def _make_loss(cfg, acfg, task):
    if task == "classification":
        def loss(tr, params, batch):
            return classifier_loss(cfg, params, tr["adapters"], acfg,
                                   tr["cls_head"], batch)
    else:
        def loss(tr, params, batch):
            return loss_fn(cfg, params, tr["adapters"], acfg, batch)
    return loss


def build(key, cfg, acfg, fed, *, task="classification", n_classes=4,
          optimizer=None, lr=1e-2, dtype=jnp.float32):
    """Construct the federated system (model, clients, jitted round)."""
    k1, k2, k3 = jax.random.split(key, 3)
    params = init_model(k1, cfg, dtype)
    single = {"adapters": init_adapters(k2, cfg, acfg)}
    if task == "classification":
        single["cls_head"] = init_classifier(k3, cfg, n_classes)
    # every client starts from the same init (paper's broadcast-at-t0)
    trainables = broadcast_clients(single, fed.n_clients)
    mask = trainable_mask(single, acfg.mode)

    if optimizer is None:
        optimizer = adamw(lr) if acfg.variant == "vera" else sgd(lr)
    opt_init, opt_update = optimizer
    opt_state = broadcast_clients(opt_init(single), fed.n_clients)

    loss = _make_loss(cfg, acfg, task)

    def client_update(tr, ost, batches):
        def step(carry, batch):
            tr, ost = carry
            lval, grads = jax.value_and_grad(loss)(tr, params, batch)
            upd, ost = opt_update(grads, ost, tr, mask)
            tr = apply_updates(tr, upd)
            return (tr, ost), lval

        (tr, ost), losses = jax.lax.scan(step, (tr, ost), batches)
        return tr, ost, jnp.mean(losses)

    @jax.jit
    def round_fn(trainables, opt_state, batches, participation):
        tr, ost, losses = jax.vmap(client_update)(trainables, opt_state,
                                                  batches)
        tr = aggregate(tr, acfg.mode, participation=participation)
        return tr, ost, losses

    # split pieces for the fault-tolerant round path (run_rounds with
    # faults=/robust=): client updates and aggregation as separate jits,
    # with host-side validation/clipping in between. Lazy — tracing only
    # happens if the robust path is actually driven.
    update_fn = jax.jit(jax.vmap(client_update))

    # trim is static: `trim > 0` picks the aggregator at trace time (one
    # compiled variant per distinct trim value, of which a run has one)
    @functools.partial(jax.jit, static_argnums=(3,))
    def agg_fn(trainables, contribute, receive, trim):
        return aggregate(trainables, acfg.mode, participation=contribute,
                         receive=receive, trim=trim)

    if task == "classification":
        @jax.jit
        def eval_fn(trainables, batch):
            def one(tr, b):
                logits, _ = encode_logits(cfg, params, tr["adapters"], acfg,
                                          tr["cls_head"], b["tokens"])
                return jnp.mean(
                    (jnp.argmax(logits, -1) == b["label"]).astype(jnp.float32))
            return jax.vmap(one)(trainables, batch)
    else:
        @jax.jit
        def eval_fn(trainables, batch):
            def one(tr, b):
                return loss_fn(cfg, params, tr["adapters"], acfg, b)
            return jax.vmap(one)(trainables, batch)

    n_tr, comm = count_params(single, acfg.mode)
    return FedSystem(cfg=cfg, acfg=acfg, fed=fed, params=params,
                     trainables=trainables, opt_state=opt_state, mask=mask,
                     round_fn=round_fn, eval_fn=eval_fn,
                     comm_per_round=comm, n_trainable=n_tr,
                     update_fn=update_fn, agg_fn=agg_fn)


def _select_clients(new, old, ok):
    """Per-client select over a client-axis tree: client c takes ``new``
    where ``ok[c]``, else keeps ``old`` (a failed update never lands)."""
    ok = jnp.asarray(ok, bool)

    def f(n, o):
        m = ok.reshape((ok.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)

    return jax.tree_util.tree_map(f, new, old)


def _robust_round(system, tr, ost, batches, part, rnd, *, last_good,
                  faults, robust, trace):
    """One fault-tolerant round: participation faults → client updates →
    corruption injection → validation gate → (trimmed) aggregation →
    aggregate guard with last-good-Ā rollback.

    Returns ``(tr, ost, losses, info)`` where ``info`` carries the
    per-round fault accounting for history/metrics.
    """
    mode = system.acfg.mode
    C = system.fed.n_clients
    part = np.asarray(part, np.float32).copy()
    info = {"dropped": [], "cutoff": [], "rejected": [], "clipped": [],
            "rolled_back": False, "retries": 0}
    delay = np.zeros((C,), np.float32)

    def emit(ev, **fields):
        if trace is not None:
            trace.emit(ev, **fields)

    if faults is not None:
        for c in range(C):
            if not part[c]:
                continue
            lost, attempts = faults.client_fate(
                rnd, c, max_retries=robust.max_retries)
            info["retries"] += attempts
            delay[c] += attempts * robust.backoff_s
            if lost:
                part[c] = 0.0
                info["dropped"].append(c)
                emit("client_dropped", round=rnd, client=c,
                     reason="dropout", retries=attempts)
                continue
            delay[c] += faults.straggler_delay(rnd, c)
    if robust.round_deadline_s is not None:
        for c in range(C):
            if part[c] and delay[c] > robust.round_deadline_s:
                part[c] = 0.0
                info["cutoff"].append(c)
                emit("client_dropped", round=rnd, client=c,
                     reason="straggler", delay_s=float(delay[c]))

    tr_new, ost_new, losses = system.update_fn(tr, ost, batches)
    failed = info["dropped"] + info["cutoff"]
    if failed:
        ok = np.ones((C,), bool)
        ok[failed] = False
        # a failed/late update never lands: those clients' trainables AND
        # optimizer state stay at the pre-round values
        tr_new = _select_clients(tr_new, tr, ok)
        ost_new = _select_clients(ost_new, ost, ok)

    if faults is not None:
        cmask = faults.corrupt_mask(rnd, C) & (part > 0)
        if cmask.any():
            tr_new = corrupt_shared(tr_new, mode, cmask,
                                    kind=faults.plan.corrupt_kind,
                                    scale=faults.plan.corrupt_scale)

    # validation gate over the SHARED updates (the Ā the whole fleet is
    # about to inherit): reject non-finite, clip norm outliers
    contribute = part.copy()
    norms, finite = shared_client_stats(tr_new, mode)
    if norms is not None:
        norms, finite = np.asarray(norms), np.asarray(finite)
        if robust.reject_nonfinite:
            for c in range(C):
                if contribute[c] and not finite[c]:
                    contribute[c] = 0.0
                    info["rejected"].append(c)
                    emit("update_rejected", round=rnd, client=c,
                         reason="nonfinite")
        if robust.outlier_mult is not None:
            valid = (contribute > 0) & finite
            if valid.any():
                med = float(np.median(norms[valid]))
                thresh = robust.outlier_mult * max(med, 1e-12)
                scale = np.ones((C,), np.float32)
                for c in range(C):
                    if valid[c] and norms[c] > thresh:
                        scale[c] = thresh / float(norms[c])
                        info["clipped"].append(c)
                if info["clipped"]:
                    tr_new = scale_shared(tr_new, mode, scale)

    # contribute: survived every gate; receive: everyone who made the
    # deadline — a rejected client is healed by the aggregate it did
    # not pollute
    tr_agg = system.agg_fn(tr_new, jnp.asarray(contribute),
                           jnp.asarray(part), float(robust.trim))

    _, agg_fin = shared_client_stats(tr_agg, mode)
    if agg_fin is not None and not bool(np.asarray(agg_fin).all()):
        # the round's aggregate is poisoned despite the gate: fall back
        # to the last-good Ā (local progress is kept)
        tr_agg = take_shared(tr_agg, last_good, mode)
        info["rolled_back"] = True
        emit("rollback", round=rnd, reason="nonfinite_aggregate")

    delivered = part > 0
    lmean = float(np.asarray(losses)[delivered].mean()) if delivered.any() \
        else float(np.asarray(losses).mean())
    return tr_agg, ost_new, lmean, info


def run_rounds(system, clients, *, rounds, batch_size, seed=0,
               eval_every=0, test_batch=None, target_acc=None,
               publish=None, publish_every=1, metrics=None,
               faults=None, robust=None, trace=None):
    """Drive the federated loop. Returns history dict.

    clients: list of per-client numpy data dicts.
    test_batch: stacked (C, ...) eval batch for eval_every / target_acc.
    publish: optional ``(round_version, trainables)`` callback streaming
    each round's post-aggregation trainables to a serving-side sink
    (e.g. ``repro.serving.AdapterFeed.publish`` — the live train→serve
    bridge); invoked every ``publish_every`` rounds with the global
    round number (1-based) as the version.
    metrics: optional ``repro.obs.MetricsRegistry``. Per-round wall time
    lands in the ``repro_fed_round_seconds`` histogram, the latest mean
    client loss in the ``repro_fed_round_loss`` gauge, and round/publish
    totals in counters — sharing the registry with a live
    ``ServingEngine`` puts train and serve metrics in one exposition.
    faults: optional ``repro.failures.FaultInjector`` — injects client
    dropout/straggling/corruption per round (deterministic in the plan
    seed) and switches the loop onto the fault-tolerant round path.
    robust: optional ``RobustConfig`` — enables the fault-tolerant path
    (straggler cutoff, bounded retry accounting, the shared-update
    validation gate, trimmed-mean aggregation, last-good-Ā rollback)
    even without an injector; defaults to ``RobustConfig()`` whenever
    ``faults`` is given. The plain path is byte-identical to before.
    trace: optional ``repro.obs.TraceLog`` for ``client_dropped`` /
    ``update_rejected`` / ``rollback`` events.
    """
    fed = system.fed
    rng = np.random.default_rng(seed)
    tr, ost = system.trainables, system.opt_state
    history = {"loss": [], "acc": [], "rounds_to_target": None}
    if faults is not None and robust is None:
        robust = RobustConfig()
    if robust is not None:
        if system.update_fn is None or system.agg_fn is None:
            raise ValueError("robust rounds need a FedSystem from build() "
                             "(update_fn/agg_fn missing)")
        history.update({"dropped": [], "rejected": [], "clipped": [],
                        "rollbacks": 0})
        last_good = tr
    if metrics is not None:
        if robust is not None:
            c_drop = metrics.counter("repro_fed_clients_dropped_total",
                                     "client updates lost to dropout or "
                                     "straggler cutoff")
            c_rej = metrics.counter("repro_fed_updates_rejected_total",
                                    "client updates rejected by the "
                                    "validation gate")
            c_clip = metrics.counter("repro_fed_updates_clipped_total",
                                     "client updates norm-clipped")
            c_roll = metrics.counter("repro_fed_rollbacks_total",
                                     "rounds rolled back to last-good Ā")
        h_round = metrics.histogram("repro_fed_round_seconds",
                                    "wall per federation round")
        g_loss = metrics.gauge("repro_fed_round_loss",
                               "mean client loss, latest round")
        c_rounds = metrics.counter("repro_fed_rounds_total",
                                   "completed federation rounds")
        c_pub = metrics.counter("repro_fed_publishes_total",
                                "rounds published to a serving feed")
    for r in range(rounds):
        t_round = time.perf_counter()
        steps = []
        for _ in range(fed.local_steps):
            steps.append(stack_client_batch(clients, batch_size, rng))
        batches = {k: jnp.asarray(np.stack([s[k] for s in steps], axis=1))
                   for k in steps[0]}          # (C, E, B, ...)
        if fed.client_sample_rate < 1.0:
            part = (rng.random(fed.n_clients)
                    < fed.client_sample_rate).astype(np.float32)
            if part.sum() == 0:
                part[rng.integers(fed.n_clients)] = 1.0
            part = jnp.asarray(part)
        else:
            part = jnp.ones((fed.n_clients,), jnp.float32)
        if robust is not None:
            tr, ost, lmean, info = _robust_round(
                system, tr, ost, batches, part, r, last_good=last_good,
                faults=faults, robust=robust, trace=trace)
            history["loss"].append(lmean)
            history["dropped"].append(info["dropped"] + info["cutoff"])
            history["rejected"].append(info["rejected"])
            history["clipped"].append(info["clipped"])
            if info["rolled_back"]:
                history["rollbacks"] += 1
            else:
                last_good = tr
            if metrics is not None:
                c_drop.inc(len(info["dropped"]) + len(info["cutoff"]))
                c_rej.inc(len(info["rejected"]))
                c_clip.inc(len(info["clipped"]))
                c_roll.inc(int(info["rolled_back"]))
        else:
            tr, ost, losses = system.round_fn(tr, ost, batches, part)
            history["loss"].append(float(jnp.mean(losses)))
        if metrics is not None:
            h_round.observe(time.perf_counter() - t_round)
            g_loss.set(history["loss"][-1])
            c_rounds.inc()
        if publish is not None and (r + 1) % publish_every == 0:
            publish(r + 1, tr)
            if metrics is not None:
                c_pub.inc()
        if eval_every and test_batch is not None and (r + 1) % eval_every == 0:
            accs = system.eval_fn(tr, test_batch)
            acc = float(jnp.mean(accs))
            history["acc"].append(acc)
            if (target_acc is not None
                    and history["rounds_to_target"] is None
                    and acc >= target_acc):
                history["rounds_to_target"] = r + 1
    system.trainables, system.opt_state = tr, ost
    return history


def centralized_reference(key, cfg, acfg, clients, *, task="classification",
                          n_classes=4, steps=100, batch_size=32, lr=1e-2,
                          seed=0):
    """Non-federated pooled-data fine-tuning (the paper's upper reference)."""
    import repro.configs.base as base
    fed = base.FedConfig(n_clients=1, local_steps=1)
    pooled = [{k: np.concatenate([c[k] for c in clients]) for k in clients[0]}]
    sys1 = build(key, cfg, acfg, fed, task=task, n_classes=n_classes, lr=lr)
    run_rounds(sys1, pooled, rounds=steps, batch_size=batch_size, seed=seed)
    return sys1
