"""Host federated runtime: vmap over clients, scan over local steps.

This is the runtime behind every accuracy experiment (paper Tables 1, 3, 4,
5, 10 and Fig. 2). Clients are a leading pytree axis; one communication
round is a single jitted call:

  round = vmap_over_clients( scan(E local SGD steps) ) ∘ selective_aggregate

The *in-mesh* (TPU pod) counterpart of the same round lives in
``repro.launch.train``; this module is the CPU-scale reference semantics.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adapters import init_adapters
from repro.core.aggregation import aggregate, broadcast_clients
from repro.core.strategies import count_params, trainable_mask
from repro.data.synthetic import stack_client_batch
from repro.models.transformer import (classifier_loss, encode_logits,
                                      init_classifier, init_model, loss_fn)
from repro.optim import adamw, apply_updates, sgd


@dataclasses.dataclass
class FedSystem:
    cfg: object
    acfg: object
    fed: object
    params: object              # frozen base model (no client axis)
    trainables: object          # client-axis adapter (+head) tree
    opt_state: object
    mask: object
    round_fn: object            # jitted (trainables, opt_state, batches, part)
    eval_fn: object
    comm_per_round: int         # parameters uploaded per client per round
    n_trainable: int


def _make_loss(cfg, acfg, task):
    if task == "classification":
        def loss(tr, params, batch):
            return classifier_loss(cfg, params, tr["adapters"], acfg,
                                   tr["cls_head"], batch)
    else:
        def loss(tr, params, batch):
            return loss_fn(cfg, params, tr["adapters"], acfg, batch)
    return loss


def build(key, cfg, acfg, fed, *, task="classification", n_classes=4,
          optimizer=None, lr=1e-2, dtype=jnp.float32):
    """Construct the federated system (model, clients, jitted round)."""
    k1, k2, k3 = jax.random.split(key, 3)
    params = init_model(k1, cfg, dtype)
    single = {"adapters": init_adapters(k2, cfg, acfg)}
    if task == "classification":
        single["cls_head"] = init_classifier(k3, cfg, n_classes)
    # every client starts from the same init (paper's broadcast-at-t0)
    trainables = broadcast_clients(single, fed.n_clients)
    mask = trainable_mask(single, acfg.mode)

    if optimizer is None:
        optimizer = adamw(lr) if acfg.variant == "vera" else sgd(lr)
    opt_init, opt_update = optimizer
    opt_state = broadcast_clients(opt_init(single), fed.n_clients)

    loss = _make_loss(cfg, acfg, task)

    def client_update(tr, ost, batches):
        def step(carry, batch):
            tr, ost = carry
            lval, grads = jax.value_and_grad(loss)(tr, params, batch)
            upd, ost = opt_update(grads, ost, tr, mask)
            tr = apply_updates(tr, upd)
            return (tr, ost), lval

        (tr, ost), losses = jax.lax.scan(step, (tr, ost), batches)
        return tr, ost, jnp.mean(losses)

    @jax.jit
    def round_fn(trainables, opt_state, batches, participation):
        tr, ost, losses = jax.vmap(client_update)(trainables, opt_state,
                                                  batches)
        tr = aggregate(tr, acfg.mode, participation=participation)
        return tr, ost, losses

    if task == "classification":
        @jax.jit
        def eval_fn(trainables, batch):
            def one(tr, b):
                logits, _ = encode_logits(cfg, params, tr["adapters"], acfg,
                                          tr["cls_head"], b["tokens"])
                return jnp.mean(
                    (jnp.argmax(logits, -1) == b["label"]).astype(jnp.float32))
            return jax.vmap(one)(trainables, batch)
    else:
        @jax.jit
        def eval_fn(trainables, batch):
            def one(tr, b):
                return loss_fn(cfg, params, tr["adapters"], acfg, b)
            return jax.vmap(one)(trainables, batch)

    n_tr, comm = count_params(single, acfg.mode)
    return FedSystem(cfg=cfg, acfg=acfg, fed=fed, params=params,
                     trainables=trainables, opt_state=opt_state, mask=mask,
                     round_fn=round_fn, eval_fn=eval_fn,
                     comm_per_round=comm, n_trainable=n_tr)


def run_rounds(system, clients, *, rounds, batch_size, seed=0,
               eval_every=0, test_batch=None, target_acc=None,
               publish=None, publish_every=1, metrics=None):
    """Drive the federated loop. Returns history dict.

    clients: list of per-client numpy data dicts.
    test_batch: stacked (C, ...) eval batch for eval_every / target_acc.
    publish: optional ``(round_version, trainables)`` callback streaming
    each round's post-aggregation trainables to a serving-side sink
    (e.g. ``repro.serving.AdapterFeed.publish`` — the live train→serve
    bridge); invoked every ``publish_every`` rounds with the global
    round number (1-based) as the version.
    metrics: optional ``repro.obs.MetricsRegistry``. Per-round wall time
    lands in the ``repro_fed_round_seconds`` histogram, the latest mean
    client loss in the ``repro_fed_round_loss`` gauge, and round/publish
    totals in counters — sharing the registry with a live
    ``ServingEngine`` puts train and serve metrics in one exposition.
    """
    fed = system.fed
    rng = np.random.default_rng(seed)
    tr, ost = system.trainables, system.opt_state
    history = {"loss": [], "acc": [], "rounds_to_target": None}
    if metrics is not None:
        h_round = metrics.histogram("repro_fed_round_seconds",
                                    "wall per federation round")
        g_loss = metrics.gauge("repro_fed_round_loss",
                               "mean client loss, latest round")
        c_rounds = metrics.counter("repro_fed_rounds_total",
                                   "completed federation rounds")
        c_pub = metrics.counter("repro_fed_publishes_total",
                                "rounds published to a serving feed")
    for r in range(rounds):
        t_round = time.perf_counter()
        steps = []
        for _ in range(fed.local_steps):
            steps.append(stack_client_batch(clients, batch_size, rng))
        batches = {k: jnp.asarray(np.stack([s[k] for s in steps], axis=1))
                   for k in steps[0]}          # (C, E, B, ...)
        if fed.client_sample_rate < 1.0:
            part = (rng.random(fed.n_clients)
                    < fed.client_sample_rate).astype(np.float32)
            if part.sum() == 0:
                part[rng.integers(fed.n_clients)] = 1.0
            part = jnp.asarray(part)
        else:
            part = jnp.ones((fed.n_clients,), jnp.float32)
        tr, ost, losses = system.round_fn(tr, ost, batches, part)
        history["loss"].append(float(jnp.mean(losses)))
        if metrics is not None:
            h_round.observe(time.perf_counter() - t_round)
            g_loss.set(history["loss"][-1])
            c_rounds.inc()
        if publish is not None and (r + 1) % publish_every == 0:
            publish(r + 1, tr)
            if metrics is not None:
                c_pub.inc()
        if eval_every and test_batch is not None and (r + 1) % eval_every == 0:
            accs = system.eval_fn(tr, test_batch)
            acc = float(jnp.mean(accs))
            history["acc"].append(acc)
            if (target_acc is not None
                    and history["rounds_to_target"] is None
                    and acc >= target_acc):
                history["rounds_to_target"] = r + 1
    system.trainables, system.opt_state = tr, ost
    return history


def centralized_reference(key, cfg, acfg, clients, *, task="classification",
                          n_classes=4, steps=100, batch_size=32, lr=1e-2,
                          seed=0):
    """Non-federated pooled-data fine-tuning (the paper's upper reference)."""
    import repro.configs.base as base
    fed = base.FedConfig(n_clients=1, local_steps=1)
    pooled = [{k: np.concatenate([c[k] for c in clients]) for k in clients[0]}]
    sys1 = build(key, cfg, acfg, fed, task=task, n_classes=n_classes, lr=lr)
    run_rounds(sys1, pooled, rounds=steps, batch_size=batch_size, seed=seed)
    return sys1
