"""Selective server aggregation — the paper's round operation.

Client adapter trees carry a leading client axis ``C`` on every leaf.
``aggregate`` replaces each *shared* leaf with its cross-client mean
(broadcast back to all clients) and leaves *local*/*frozen* leaves
untouched. Under ``jit`` inside the in-mesh runtime the mean lowers to an
``all-reduce`` over the client mesh axis of the shared leaves only —
FedSA's halved communication is directly visible as halved collective
bytes in the dry-run HLO.

Supports weighted aggregation (client dataset sizes) and partial
participation (a 0/1 mask over clients: non-participants keep their leaf
and are excluded from the mean).

Robustness extensions (used by the fault-tolerant round path in
``core.federation`` — see ``docs/robustness.md``):

  * ``receive`` decouples who GETS the aggregate from who CONTRIBUTES
    to it: a client whose update was rejected by the validation gate is
    excluded from the mean but still receives the healthy aggregate
    (the heal path for NaN-corrupted shared leaves);
  * ``trim`` switches the shared-leaf mean to a coordinate-wise trimmed
    mean (drop the ``trim`` fraction of extreme values per coordinate),
    the classic Byzantine-tolerant aggregator;
  * ``shared_client_stats`` / ``scale_shared`` back the validation gate:
    per-client finiteness + update norms, and norm-outlier clipping.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.strategies import SHARED, leaf_role


def _trimmed_mean(leaf, valid, trim):
    """Coordinate-wise trimmed mean over the clients marked ``valid``.

    Sorts each coordinate across the client axis (invalid clients pushed
    to +Inf, i.e. past every valid rank), then averages ranks
    ``[k, m - k)`` where ``m`` is the valid count and
    ``k = floor(trim * m)`` — so the ``trim`` fraction of extreme values
    is dropped from EACH end per coordinate. Weights are intentionally
    ignored: rank-based trimming has no principled weighted analogue.
    """
    C = leaf.shape[0]
    x = leaf.astype(jnp.float32)
    keep_shape = (C,) + (1,) * (leaf.ndim - 1)
    v = valid.astype(bool).reshape(keep_shape)
    xs = jnp.sort(jnp.where(v, x, jnp.inf), axis=0)
    m = jnp.sum(valid.astype(jnp.int32))
    k = jnp.floor(trim * m).astype(jnp.int32)
    # never trim everything: fall back to the plain mean of the valid set
    k = jnp.where(2 * k >= m, 0, k)
    rank = jnp.arange(C, dtype=jnp.int32).reshape(keep_shape)
    w = (rank >= k) & (rank < m - k)
    total = jnp.sum(jnp.where(w, xs, 0.0), axis=0)
    return total / jnp.maximum(m - 2 * k, 1).astype(jnp.float32)


def aggregate(client_adapters, mode, weights=None, participation=None,
              receive=None, trim=0.0):
    """One server round.

    client_adapters: pytree with leading client axis C on every leaf.
    weights: optional (C,) aggregation weights (e.g. dataset sizes).
    participation: optional (C,) 0/1 mask of clients CONTRIBUTING to the
    mean.
    receive: optional (C,) 0/1 mask of clients that get the aggregate
    broadcast back (defaults to ``participation``). A client in
    ``receive`` but not ``participation`` is healed: it adopts the
    aggregate without polluting it — the robust round path puts
    validation-rejected clients here.
    trim: coordinate-wise trimmed-mean fraction in [0, 0.5); 0 keeps the
    paper's weighted mean.
    """
    def agg_leaf(path, leaf):
        if leaf_role(path, mode) != SHARED:
            return leaf
        C = leaf.shape[0]
        if trim > 0.0:
            valid = (jnp.ones((C,), jnp.float32) if participation is None
                     else participation.astype(jnp.float32))
            mean = _trimmed_mean(leaf, valid, trim).astype(leaf.dtype)
        else:
            w = jnp.ones((C,), jnp.float32) if weights is None \
                else weights.astype(jnp.float32)
            x = leaf.astype(jnp.float32)
            if participation is not None:
                w = w * participation.astype(jnp.float32)
                # zero excluded leaves outright: 0-weight × NaN is NaN,
                # so masking via weights alone would let a rejected
                # client's non-finite update poison the mean
                keep_c = participation.astype(bool).reshape(
                    (C,) + (1,) * (leaf.ndim - 1))
                x = jnp.where(keep_c, x, 0.0)
            w = w / jnp.maximum(jnp.sum(w), 1e-9)
            mean = jnp.tensordot(w, x, axes=(0, 0)).astype(leaf.dtype)
        new = jnp.broadcast_to(mean[None], leaf.shape)
        recv = receive if receive is not None else participation
        if recv is not None:
            keep = recv.reshape((C,) + (1,) * (leaf.ndim - 1))
            new = jnp.where(keep.astype(bool), new, leaf)
        return new

    return jax.tree_util.tree_map_with_path(agg_leaf, client_adapters)


def shared_client_stats(client_adapters, mode):
    """Per-client validation inputs over the SHARED leaves.

    Returns ``(norms, finite)`` — (C,) float32 global L2 norm of each
    client's shared-leaf update and (C,) bool all-finite flag. The
    robust round path rejects non-finite updates outright and clips
    norm outliers before aggregation (``docs/robustness.md``).
    """
    flat = jax.tree_util.tree_flatten_with_path(client_adapters)[0]
    sq = fin = None
    for path, leaf in flat:
        if leaf_role(path, mode) != SHARED:
            continue
        x = jnp.reshape(leaf.astype(jnp.float32), (leaf.shape[0], -1))
        ok = jnp.all(jnp.isfinite(x), axis=1)
        s = jnp.sum(jnp.where(jnp.isfinite(x), x, 0.0) ** 2, axis=1)
        sq = s if sq is None else sq + s
        fin = ok if fin is None else fin & ok
    if sq is None:                       # no shared leaves under this mode
        return None, None
    return jnp.sqrt(sq), fin


def scale_shared(client_adapters, mode, scale):
    """Multiply each client's SHARED leaves by its (C,) ``scale`` —
    the norm-outlier clipping step (scale 1.0 = untouched)."""
    scale = jnp.asarray(scale, jnp.float32)

    def f(path, leaf):
        if leaf_role(path, mode) != SHARED:
            return leaf
        s = scale.reshape((leaf.shape[0],) + (1,) * (leaf.ndim - 1))
        return (leaf.astype(jnp.float32) * s).astype(leaf.dtype)

    return jax.tree_util.tree_map_with_path(f, client_adapters)


def take_shared(dst, src, mode):
    """Replace ``dst``'s SHARED leaves with ``src``'s — the last-good-Ā
    rollback: local progress is kept, the aggregate reverts."""
    def f(path, d, s):
        return s if leaf_role(path, mode) == SHARED else d
    return jax.tree_util.tree_map_with_path(f, dst, src)


def corrupt_shared(client_adapters, mode, mask, *, kind="nan", scale=1e6):
    """Fault-injection helper: corrupt the SHARED leaves of clients in
    ``mask`` (C,) — NaN fill or a ``scale``× blow-up (the divergent-A
    mode). Used by ``FaultInjector`` consumers; local leaves untouched."""
    mask = jnp.asarray(mask)

    def f(path, leaf):
        if leaf_role(path, mode) != SHARED:
            return leaf
        m = mask.reshape((leaf.shape[0],) + (1,) * (leaf.ndim - 1))
        if kind == "nan":
            bad = jnp.full_like(leaf, jnp.nan)
        else:
            bad = leaf * jnp.asarray(scale, leaf.dtype)
        return jnp.where(m, bad, leaf)

    return jax.tree_util.tree_map_with_path(f, client_adapters)


def broadcast_clients(adapters, n_clients):
    """Replicate a single adapter tree across a new leading client axis."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape), adapters)


def comm_bytes(adapters_single_client, mode, dtype_bytes=4):
    """Per-round, per-client upload volume in bytes (Table 2)."""
    from repro.core.strategies import count_params
    _, comm = count_params(adapters_single_client, mode)
    return comm * dtype_bytes
