"""Selective server aggregation — the paper's round operation.

Client adapter trees carry a leading client axis ``C`` on every leaf.
``aggregate`` replaces each *shared* leaf with its cross-client mean
(broadcast back to all clients) and leaves *local*/*frozen* leaves
untouched. Under ``jit`` inside the in-mesh runtime the mean lowers to an
``all-reduce`` over the client mesh axis of the shared leaves only —
FedSA's halved communication is directly visible as halved collective
bytes in the dry-run HLO.

Supports weighted aggregation (client dataset sizes) and partial
participation (a 0/1 mask over clients: non-participants keep their leaf
and are excluded from the mean).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.strategies import SHARED, leaf_role


def aggregate(client_adapters, mode, weights=None, participation=None):
    """One server round.

    client_adapters: pytree with leading client axis C on every leaf.
    weights: optional (C,) aggregation weights (e.g. dataset sizes).
    participation: optional (C,) 0/1 mask of sampled clients.
    """
    def agg_leaf(path, leaf):
        if leaf_role(path, mode) != SHARED:
            return leaf
        C = leaf.shape[0]
        w = jnp.ones((C,), jnp.float32) if weights is None \
            else weights.astype(jnp.float32)
        if participation is not None:
            w = w * participation.astype(jnp.float32)
        w = w / jnp.maximum(jnp.sum(w), 1e-9)
        mean = jnp.tensordot(w, leaf.astype(jnp.float32), axes=(0, 0))
        mean = mean.astype(leaf.dtype)
        new = jnp.broadcast_to(mean[None], leaf.shape)
        if participation is not None:
            keep = participation.reshape((C,) + (1,) * (leaf.ndim - 1))
            new = jnp.where(keep.astype(bool), new, leaf)
        return new

    return jax.tree_util.tree_map_with_path(agg_leaf, client_adapters)


def broadcast_clients(adapters, n_clients):
    """Replicate a single adapter tree across a new leading client axis."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape), adapters)


def comm_bytes(adapters_single_client, mode, dtype_bytes=4):
    """Per-round, per-client upload volume in bytes (Table 2)."""
    from repro.core.strategies import count_params
    _, comm = count_params(adapters_single_client, mode)
    return comm * dtype_bytes
