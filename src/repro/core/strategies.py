"""Leaf roles under each federated aggregation mode — the paper's §4.

Every adapter leaf is classified as one of
  ``shared``  — aggregated on the server each round (FedAvg mean),
  ``local``   — trainable but kept on the client (personalization),
  ``frozen``  — never updated (masked out of the optimizer).

| mode   | A / d            | B / b            | notes                      |
|--------|------------------|------------------|----------------------------|
| fedavg | shared           | shared           | vanilla LoRA+FL (Eq. 1)    |
| ffa    | frozen           | shared           | FFA-LoRA (Sun et al. 24)   |
| fedsa  | shared           | local            | THIS PAPER (Eq. 2)         |
| fedit  | local            | local            | FedIT-style plain LoRA     |
|        |                  |                  | served per client: each    |
|        |                  |                  | tenant keeps its own A_i   |
|        |                  |                  | AND B_i (pure personal-    |
|        |                  |                  | ization; nothing is        |
|        |                  |                  | aggregated)                |
| feddpa | global: shared   | global: shared   | dual adapters: the whole   |
|        | personal: local  | personal: local  | personal leaf pair local   |

``vera_shared`` matrices are always frozen (VeRA's defining trait).
Classification-head leaves (used by the GLUE-proxy benchmarks) are shared
under every mode, matching the paper's setup.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SHARED, LOCAL, FROZEN = "shared", "local", "frozen"


def _path_names(path):
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
    return names


def leaf_role(path, mode):
    """Role of one adapter leaf. ``path`` is a jax key-path tuple."""
    names = _path_names(path)
    if "vera_shared" in names:
        return FROZEN
    if "cls_head" in names:
        return SHARED
    if mode == "feddpa":
        if "global" in names:
            return SHARED
        if "personal" in names:
            return LOCAL
        return SHARED  # non-adapter trainables (e.g. head)
    leaf_name = names[-1]
    is_a = leaf_name in ("A", "d")
    is_b = leaf_name in ("B", "b")
    if mode == "fedavg":
        return SHARED
    if mode == "ffa":
        return FROZEN if is_a else SHARED
    if mode == "fedsa":
        return SHARED if is_a else (LOCAL if is_b else SHARED)
    if mode == "fedit":
        # serving-side notion of the FedIT / plain-LoRA baseline: every
        # client owns its local adapter pair (the pre-aggregation state a
        # personal-adapter deployment actually serves), so both matrices
        # are per-client and nothing is communicated
        return LOCAL if (is_a or is_b) else SHARED
    raise ValueError(f"unknown mode {mode!r}")


def role_tree(adapters, mode):
    """Pytree of role strings with the same structure as ``adapters``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, _: leaf_role(path, mode), adapters)


def trainable_mask(adapters, mode):
    """1.0 for trainable leaves (shared|local), 0.0 for frozen."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: jnp.asarray(
            0.0 if leaf_role(path, mode) == FROZEN else 1.0,
            dtype=jnp.float32),
        adapters)


def count_params(adapters, mode):
    """(trainable, communicated-per-round) parameter counts for Table 2."""
    trainable = 0
    communicated = 0
    flat = jax.tree_util.tree_flatten_with_path(adapters)[0]
    for path, leaf in flat:
        role = leaf_role(path, mode)
        if role != FROZEN:
            trainable += leaf.size
        if role == SHARED:
            communicated += leaf.size
    return trainable, communicated
