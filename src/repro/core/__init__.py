"""FedSA-LoRA core: the paper's contribution as a composable JAX module.

* ``adapters``    — LoRA / rsLoRA / VeRA adapter trees over any model
* ``strategies``  — shared/local/frozen leaf roles per federated mode
* ``aggregation`` — selective server aggregation (the paper's Eq. 2)
* ``federation``  — host federated runtime (vmap clients × scan steps)
* ``similarity``  — Fig. 2 cross-client A/B similarity analysis
* ``sketch``      — FetchSGD count-sketch A-update compression (Table 10)
"""
from repro.core.adapters import init_adapters, n_params
from repro.core.aggregation import aggregate, broadcast_clients, comm_bytes
from repro.core.strategies import (FROZEN, LOCAL, SHARED, count_params,
                                   leaf_role, role_tree, trainable_mask)

__all__ = [
    "init_adapters", "n_params", "aggregate", "broadcast_clients",
    "comm_bytes", "FROZEN", "LOCAL", "SHARED", "count_params", "leaf_role",
    "role_tree", "trainable_mask",
]
