"""Cross-client similarity analysis of learned adapter matrices (Fig. 2).

The paper's empirical foundation: after local fine-tuning, A matrices are
similar across clients while B matrices diverge, increasingly so with data
heterogeneity. ``pairwise_similarity`` reproduces the measurement: mean
pairwise cosine similarity of flattened leaves across clients, grouped by
leaf name (A vs B, or VeRA's d vs b).
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np


def _cos(u, v):
    u = u.reshape(-1).astype(jnp.float32)
    v = v.reshape(-1).astype(jnp.float32)
    nu = jnp.linalg.norm(u)
    nv = jnp.linalg.norm(v)
    return jnp.dot(u, v) / jnp.maximum(nu * nv, 1e-12)


def pairwise_similarity(client_adapters):
    """Mean pairwise cosine similarity per leaf name.

    client_adapters: pytree with leading client axis C. Returns
    {leaf_name: float} averaged over all modules/layers and client pairs.
    """
    flat = jax.tree_util.tree_flatten_with_path(client_adapters)[0]
    sums, counts = {}, {}
    for path, leaf in flat:
        names = [str(p.key) for p in path if hasattr(p, "key")]
        if "vera_shared" in names:
            continue
        name = names[-1]
        C = leaf.shape[0]
        if C < 2:
            continue
        flatl = leaf.reshape(C, -1)
        for i, j in itertools.combinations(range(C), 2):
            s = float(_cos(flatl[i], flatl[j]))
            sums[name] = sums.get(name, 0.0) + s
            counts[name] = counts.get(name, 0) + 1
    return {k: sums[k] / counts[k] for k in sums}


def update_similarity(client_adapters, init_adapters):
    """Cosine similarity of learned vs initialized leaves per client
    (Fig. 4: confirms A actually moves)."""
    def path_key(path):
        return tuple(str(p.key) if hasattr(p, "key") else str(p.idx)
                     for p in path)

    flat_c = jax.tree_util.tree_flatten_with_path(client_adapters)[0]
    flat_0 = {path_key(path): leaf
              for path, leaf in
              jax.tree_util.tree_flatten_with_path(init_adapters)[0]}
    out = {}
    for path, leaf in flat_c:
        key = path_key(path)
        names = [str(p.key) for p in path if hasattr(p, "key")]
        if "vera_shared" in names:
            continue
        name = names[-1]
        init = flat_0[key]
        C = leaf.shape[0]
        sims = [float(_cos(leaf[i], init)) for i in range(C)]
        out.setdefault(name, []).extend(sims)
    return {k: float(np.mean(v)) for k, v in out.items()}
