"""Synthetic federated datasets with planted general/client-specific structure.

The container has no GLUE/GSM8K, so the paper's *relative* claims are tested
on synthetic tasks engineered to have the same two ingredients the paper's
analysis rests on:

* **general knowledge** — a label↔token-pattern mapping shared by every
  client (what the aggregated A should capture);
* **client-specific knowledge** — a per-client input transformation
  (a client-private remapping of part of the vocabulary, i.e. a shift of
  ``E[x xᵀ]``) plus Dirichlet label skew (what a local B can absorb but a
  shared update cannot).

``make_classification_task`` → the GLUE-proxy (sequence classification).
``make_lm_task``            → the NLG-proxy (Markov-chain language model).
Both return per-client numpy arrays; ``client_batches`` yields jnp batches.
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels, n_clients, alpha, rng, min_per_client=8):
    """Index lists per client; alpha=None → IID split."""
    n = len(labels)
    if alpha is None:
        idx = rng.permutation(n)
        return np.array_split(idx, n_clients)
    classes = np.unique(labels)
    client_idx = [[] for _ in range(n_clients)]
    for c in classes:
        pool = rng.permutation(np.where(labels == c)[0])
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(pool)).astype(int)[:-1]
        for i, part in enumerate(np.split(pool, cuts)):
            client_idx[i].extend(part.tolist())
    out = []
    for i in range(n_clients):
        arr = rng.permutation(np.array(client_idx[i], dtype=np.int64))
        out.append(arr)
    # guarantee a floor so vmap'd batching never sees an empty client
    for i in range(n_clients):
        if len(out[i]) < min_per_client:
            donor = int(np.argmax([len(o) for o in out]))
            need = min_per_client - len(out[i])
            out[i] = np.concatenate([out[i], out[donor][:need]])
            out[donor] = out[donor][need:]
    return out


def _client_token_maps(vocab, n_clients, strength, rng):
    """Per-client permutation of a fraction of the vocabulary (the planted
    client-specific input shift). strength ∈ [0,1] = fraction remapped."""
    maps = []
    n_remap = int(vocab * strength)
    for _ in range(n_clients):
        m = np.arange(vocab)
        if n_remap >= 2:
            src = rng.choice(vocab, size=n_remap, replace=False)
            m[src] = rng.permutation(src)
        maps.append(m)
    return maps


def _client_label_maps(n_classes, n_clients, concept_shift, rng):
    """Per-client permutation of a ``concept_shift`` fraction of classes —
    CONFLICTING conditionals P_i(y|x), the regime where a single global
    update cannot fit every client and personalization (local B) pays off.
    Client 0 keeps the identity mapping (a reference client)."""
    n_perm = int(round(n_classes * concept_shift))
    if concept_shift > 0 and n_perm < 2:
        n_perm = 2                     # a permutation needs ≥ 2 classes
    maps = [np.arange(n_classes)]
    for _ in range(n_clients - 1):
        m = np.arange(n_classes)
        if n_perm >= 2:
            cls = rng.choice(n_classes, n_perm, replace=False)
            m[cls] = np.roll(cls, 1)   # cyclic → guaranteed derangement
        maps.append(m)
    return maps


def make_classification_task(n_clients=3, n_classes=4, vocab=512, seq=32,
                             n_train=1024, n_test=512, alpha=0.5,
                             hetero_strength=0.3, concept_shift=None,
                             n_signal=4, seed=0):
    """GLUE-proxy: classify which planted token pattern a sequence carries.

    Each class owns ``n_signal`` signature tokens; a sequence is background
    noise with signature tokens planted at random positions (the GENERAL
    knowledge every client shares). Clients see the data through three
    heterogeneity channels:
      * Dirichlet(alpha) label skew,
      * a private remap of ``hetero_strength`` of the vocabulary
        (input-distribution shift — moves E[x xᵀ]),
      * a private permutation of ``concept_shift`` of the classes
        (conflicting conditionals — what local B matrices absorb).
    ``concept_shift`` defaults to ``hetero_strength``.
    """
    rng = np.random.default_rng(seed)
    concept_shift = hetero_strength if concept_shift is None else \
        concept_shift
    sig = rng.choice(np.arange(vocab // 2, vocab), (n_classes, n_signal),
                     replace=False)

    def gen(n):
        labels = rng.integers(0, n_classes, n)
        toks = rng.integers(0, vocab // 2, (n, seq))
        for i in range(n):
            pos = rng.choice(seq, n_signal, replace=False)
            toks[i, pos] = sig[labels[i]]
        return toks.astype(np.int32), labels.astype(np.int32)

    x_tr, y_tr = gen(n_train)
    x_te, y_te = gen(n_test)
    parts = dirichlet_partition(y_tr, n_clients, alpha, rng)
    maps = _client_token_maps(vocab, n_clients, hetero_strength, rng)
    lmaps = _client_label_maps(n_classes, n_clients, concept_shift, rng)
    clients = []
    for i in range(n_clients):
        xi = maps[i][x_tr[parts[i]]]
        clients.append({"tokens": xi.astype(np.int32),
                        "label": lmaps[i][y_tr[parts[i]]].astype(np.int32)})
    # per-client test views (personalized eval, like the paper's local test)
    tests = [{"tokens": maps[i][x_te].astype(np.int32),
              "label": lmaps[i][y_te].astype(np.int32)}
             for i in range(n_clients)]
    return clients, tests


def make_lm_task(n_clients=3, vocab=256, seq=64, n_train=512, n_test=128,
                 alpha=None, hetero_strength=0.3, seed=0):
    """NLG-proxy: next-token prediction on client-flavoured Markov chains.

    A global sparse bigram transition matrix is shared (general knowledge);
    each client interpolates it with a private random transition matrix
    (client-specific knowledge). ``alpha`` unused (no labels) but kept for
    interface symmetry.
    """
    rng = np.random.default_rng(seed)

    def sparse_rows(k=8):
        T = np.zeros((vocab, vocab))
        for v in range(vocab):
            nxt = rng.choice(vocab, k, replace=False)
            T[v, nxt] = rng.dirichlet([1.0] * k)
        return T

    T_global = sparse_rows()
    clients, tests = [], []
    for i in range(n_clients):
        T_i = (1 - hetero_strength) * T_global + hetero_strength * sparse_rows()
        T_i = T_i / T_i.sum(-1, keepdims=True)

        def sample(n):
            out = np.zeros((n, seq + 1), np.int32)
            out[:, 0] = rng.integers(0, vocab, n)
            for t in range(seq):
                p = T_i[out[:, t]]
                out[:, t + 1] = np.array(
                    [rng.choice(vocab, p=p[j]) for j in range(n)])
            return out

        tr = sample(n_train // n_clients)
        te = sample(n_test // n_clients)
        clients.append({"tokens": tr[:, :-1], "labels": tr[:, 1:]})
        tests.append({"tokens": te[:, :-1], "labels": te[:, 1:]})
    return clients, tests


def client_batches(client_data, batch_size, rng):
    """One epoch of shuffled batches for a single client's dict of arrays."""
    n = len(next(iter(client_data.values())))
    order = rng.permutation(n)
    for s in range(0, n - batch_size + 1, batch_size):
        idx = order[s:s + batch_size]
        yield {k: v[idx] for k, v in client_data.items()}


def stack_client_batch(clients, batch_size, rng):
    """One synchronized batch with a leading client axis (for vmap).

    Samples WITH replacement per client so heterogeneous client sizes still
    produce a rectangular (C, B, ...) batch.
    """
    outs = []
    for c in clients:
        n = len(next(iter(c.values())))
        idx = rng.integers(0, n, batch_size)
        outs.append({k: v[idx] for k, v in c.items()})
    return {k: np.stack([o[k] for o in outs]) for k in outs[0]}
