from repro.data.synthetic import (
    dirichlet_partition,
    make_classification_task,
    make_lm_task,
    client_batches,
)

__all__ = ["dirichlet_partition", "make_classification_task", "make_lm_task",
           "client_batches"]
