"""Hierarchical adapter store: pinned-host-RAM ring → cold npz store.

The ``AdapterRegistry``'s HBM slot tables only ever hold the hot set;
this module is everything BELOW them. A million-tenant fleet (the
FedSA-LoRA deployment reality: one personal B_i — and under
FedIT/FedDPA a personal A_i — per client) tiers as

  HBM slot tables      n_slots dense tables, gathered per decode row
  host ring            ``host_ring_slots`` clients' LOCAL leaves as
                       preformatted, slot-shaped, table-dtype numpy
                       arrays — a miss is ONE device transfer per leaf,
                       no host-side conversion on the admission path
  cold store           every other client; ``checkpoint/npz`` atomic
                       files under ``cold_dir`` (or an in-memory dict
                       when no directory is given)

Eviction demotes down a tier instead of discarding: an HBM eviction
leaves the client warm in the host ring; a host-ring overflow demotes
the LRU client to cold. Demotion is write-once — a host entry whose
bytes already sit in the cold store (every entry starts there or was
promoted from there unchanged) drops without touching the disk, so
steady-state ring churn costs dict moves, not fsyncs.

``Prefetcher`` is the async half: a daemon thread draining a queue of
client ids, promoting each cold entry into the host ring while the
engine's fused scan runs on device. The registry issues prefetches from
the scheduler's admission lookahead (the bounded queue already names
the next admits); by the time those requests reach ``acquire`` the miss
is a host-hit instead of a cold stall.

Round-trip fidelity: demote→promote must be bit-exact (the versioned
double-buffer and paired A/B tables are rewritten from store bytes at
every flip). npz preserves dtype and bits verbatim, and entries are
converted to the table dtype ONCE at ``put`` — after that the bytes
never change shape or dtype on any tier transition.
"""
from __future__ import annotations

import os
import queue
import threading
from collections import OrderedDict

import numpy as np

from repro.checkpoint.npz import _atomic_savez

_COLD_PREFIX = "adapter_"


class AdapterStore:
    """Two host-side tiers under the HBM slot tables.

    ``host_ring_slots=None`` keeps every entry in the (unbounded) host
    tier — exactly the pre-tiering registry behavior, zero cold traffic.
    ``host_ring_slots=0`` forces everything cold (the
    evict-and-reingest-from-cold baseline arm in
    ``benchmarks/serving_tiering.py``).

    Entries are lists of numpy arrays (one per LOCAL leaf, in leaf
    order), preformatted to the registry's table dtypes via ``formats``.
    All tier state is guarded by one lock — ``put``/``fetch`` run on the
    engine thread while the ``Prefetcher`` promotes on its own.
    """

    def __init__(self, *, host_ring_slots=None, cold_dir=None,
                 formats=None):
        self.host_ring_slots = host_ring_slots
        self.cold_dir = cold_dir
        self.formats = formats          # per-leaf np dtypes (or None)
        if cold_dir is not None:
            os.makedirs(cold_dir, exist_ok=True)
        self._host = OrderedDict()      # cid → [np leaves], LRU order
        self._cold_mem = {}             # cid → [np leaves] (no cold_dir)
        self._cold_ids = set()          # cids with a cold copy
        self._clean = set()             # host cids whose cold copy matches
        self._lock = threading.RLock()
        # tier counters (read via .counters; registry mirrors into obs)
        self.host_hits = 0              # fetches served from the ring
        self.cold_misses = 0            # fetches that had to go cold
        self.promotions = 0             # cold → host ring
        self.demotions = 0              # host ring → cold

    # -- dict-compatible surface (the registry's old ``_store`` uses) ------
    def __contains__(self, cid):
        with self._lock:
            return cid in self._host or cid in self._cold_ids

    def __len__(self):
        with self._lock:
            return len(self._host) + len(self._cold_ids - set(self._host))

    def __setitem__(self, cid, leaves):
        self.put(cid, leaves)

    def __getitem__(self, cid):
        return self.fetch(cid)[0]

    # -- tier operations ---------------------------------------------------
    def _format(self, leaves):
        if self.formats is None:
            return [np.asarray(x) for x in leaves]
        return [np.ascontiguousarray(x, dtype=dt)
                for x, dt in zip(leaves, self.formats)]

    def put(self, cid, leaves):
        """Ingest/overwrite a client's leaves into the host tier (the
        authoritative write path — ingest, publish commit). A stale cold
        copy is invalidated, and ring overflow demotes the LRU entry."""
        leaves = self._format(leaves)
        with self._lock:
            if self.host_ring_slots == 0:
                # no ring: straight to cold
                self._host.pop(cid, None)
                self._clean.discard(cid)
                self._cold_write(cid, leaves)
                return
            self._host[cid] = leaves
            self._host.move_to_end(cid)
            self._clean.discard(cid)    # new bytes: any cold copy is stale
            self._spill()

    def fetch(self, cid):
        """(leaves, tier) — tier is "host" or "cold". A cold fetch loads
        synchronously (the only stalling path) and promotes the entry
        into the ring. Raises KeyError for never-ingested clients."""
        with self._lock:
            got = self._host.get(cid)
            if got is not None:
                self._host.move_to_end(cid)
                self.host_hits += 1
                return got, "host"
            if cid not in self._cold_ids:
                raise KeyError(cid)
            self.cold_misses += 1
            leaves = self._promote(cid)
            return leaves, "cold"

    def touch(self, cid):
        """Mark a host-ring entry most-recently-used (the registry calls
        this when an HBM eviction demotes a slot: the bytes drop ONE
        tier, to the ring — a cold entry stays cold, no promotion I/O on
        the admission path)."""
        with self._lock:
            if cid in self._host:
                self._host.move_to_end(cid)

    def tier_of(self, cid):
        """"host" | "cold" | None (never ingested). Pure peek: no LRU
        movement, no promotion, no counter."""
        with self._lock:
            if cid in self._host:
                return "host"
            if cid in self._cold_ids:
                return "cold"
            return None

    def prefetch(self, cid):
        """Promote ``cid`` host-ward if it is cold. Returns True when a
        promotion happened (the Prefetcher's unit of work)."""
        with self._lock:
            if cid in self._host or cid not in self._cold_ids:
                return False
            self._promote(cid)
            return True

    def _promote(self, cid):
        """Cold → host ring (lock held). The loaded bytes ARE the cold
        bytes (no reformat — they were formatted at put), so the entry
        is born clean: a later demotion is a free drop."""
        leaves = self._cold_read(cid)
        if self.host_ring_slots == 0:
            return leaves                # no ring to promote into
        self.promotions += 1
        self._host[cid] = leaves
        self._host.move_to_end(cid)
        self._clean.add(cid)
        self._spill()
        return leaves

    def _spill(self):
        """Demote LRU host entries past the ring bound (lock held)."""
        if self.host_ring_slots is None:
            return
        while len(self._host) > self.host_ring_slots:
            victim, leaves = self._host.popitem(last=False)
            self.demotions += 1
            if victim in self._clean:    # cold copy already current
                self._clean.discard(victim)
                continue
            self._cold_write(victim, leaves)

    # -- cold tier I/O -----------------------------------------------------
    def _cold_path(self, cid):
        return os.path.join(self.cold_dir, f"{_COLD_PREFIX}{cid}.npz")

    def _cold_write(self, cid, leaves):
        if self.cold_dir is None:
            self._cold_mem[cid] = leaves
        else:
            _atomic_savez(self._cold_path(cid),
                          {f"leaf_{i}": x for i, x in enumerate(leaves)})
        self._cold_ids.add(cid)

    def _cold_read(self, cid):
        if self.cold_dir is None:
            return self._cold_mem[cid]
        with np.load(self._cold_path(cid)) as data:
            return [data[f"leaf_{i}"] for i in range(len(data.files))]

    # -- views -------------------------------------------------------------
    @property
    def host_count(self):
        with self._lock:
            return len(self._host)

    @property
    def cold_count(self):
        """Entries whose CURRENT bytes live only in the cold tier."""
        with self._lock:
            return len(self._cold_ids - set(self._host))

    @property
    def counters(self):
        with self._lock:
            return {"host_hits": self.host_hits,
                    "cold_misses": self.cold_misses,
                    "promotions": self.promotions,
                    "demotions": self.demotions}

    def reset_counters(self):
        with self._lock:
            self.host_hits = self.cold_misses = 0
            self.promotions = self.demotions = 0

    def migrate_from(self, other):
        """Adopt every entry of ``other`` (oldest first, so LRU order
        carries over) — used when an engine retrofits tiering onto a
        registry built with the default unbounded store."""
        with other._lock:
            entries = list(other._host.items())
            cold = [(cid, other._cold_read(cid))
                    for cid in sorted(other._cold_ids - set(other._host))]
        for cid, leaves in cold + entries:
            self.put(cid, leaves)


class Prefetcher:
    """Daemon thread promoting cold adapters host-ward.

    ``request(cid)`` enqueues (deduplicating against work already
    queued); the thread drains via ``AdapterStore.prefetch``. The engine
    issues requests at host-sync boundaries, so promotion I/O overlaps
    the device scan instead of the admission path. ``drain()`` blocks
    until the queue is empty AND the in-flight item finished — the
    deterministic handle tests and benchmarks use.
    """

    def __init__(self, store):
        self.store = store
        self.issued = 0                  # requests accepted (deduped)
        self.completed = 0               # promotions actually performed
        self._q = queue.Queue()
        self._pending = set()
        self._lock = threading.Lock()
        self._idle = threading.Event()
        self._idle.set()
        self._stop = False
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="adapter-prefetch")
        self._thread.start()

    def request(self, cid):
        """Queue a host-ward promotion; returns True when enqueued
        (False: already queued/in flight, or already host-resident)."""
        if self.store.tier_of(cid) != "cold":
            return False
        with self._lock:
            if cid in self._pending:
                return False
            self._pending.add(cid)
            self.issued += 1
            self._idle.clear()
        self._q.put(cid)
        return True

    def _run(self):
        while True:
            cid = self._q.get()
            if cid is None:
                return
            try:
                if self.store.prefetch(cid):
                    self.completed += 1
            except Exception:
                pass                     # a failed prefetch is only a
                                         # missed overlap; acquire will
                                         # take the cold path and raise
                                         # anything real
            finally:
                with self._lock:
                    self._pending.discard(cid)
                    if not self._pending and self._q.empty():
                        self._idle.set()

    def drain(self, timeout=5.0):
        """Wait for all queued prefetches to finish (tests/benches)."""
        return self._idle.wait(timeout)

    def stop(self, timeout=5.0):
        """Shut the thread down and JOIN it (bounded). Returns True when
        it exited within the timeout — an unjoined worker leaking across
        tests is how xdist runs turn flaky, so callers can assert on
        this instead of fire-and-forgetting the sentinel."""
        self._stop = True
        self._q.put(None)
        self._thread.join(timeout)
        return not self._thread.is_alive()
