"""Automatic prefix cache for the paged KV pool.

Prompts are chunked into page-aligned spans of ``chunk_pages`` pages and
chain-hashed: chunk ``i``'s key digests its parent's key plus its own
token bytes, and the chain is rooted in a *namespace* — the adapter
identity ``(client_id, store_seq)`` the row will decode under (degraded
rows use a base-model sentinel). Two prompts therefore share cached
pages only when BOTH the full token prefix AND the adapter bytes that
produced the KV match; publishing new bytes for a client bumps its
store sequence, so stale prefixes miss automatically — no invalidation
sweep.

Two entry kinds live in one LRU map:

* **chunk** entries — ``chunk_pages`` whole pages of KV for one
  page-aligned span. Hits shorten prefill to the divergent suffix.
* **tail** entries — the final *partial* page(s) of a prompt, keyed by
  (last chunk key, tail token bytes). A tail hit upgrades a chunk-level
  hit to a full-prompt hit; the first decode token then lands in a
  shared page and triggers the row's one copy-on-write.

The cache holds its own ``PagePool`` reference on every cached page
(``pool.share``), so a donor row retiring leaves its prefix resident.
``evict_for`` walks LRU→MRU under pool pressure and reclaims entries no
live row shares (refcount 1 == cache-only) — reclaim-before-shed.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict

import numpy as np


def _digest(parent, tokens, kind=b"C"):
    """Chain key: parent key + this span's token bytes. ``kind`` keeps
    chunk and tail keys disjoint even for identical token spans."""
    h = hashlib.blake2b(kind + parent, digest_size=16)
    h.update(np.ascontiguousarray(tokens, np.int32).tobytes())
    return h.digest()


def _root(ns):
    return hashlib.blake2b(repr(ns).encode(), digest_size=16).digest()


class PrefixCache:
    """LRU map of chain-hash key → cached physical page ids."""

    def __init__(self, pool, *, chunk_pages=1, trace=None):
        assert chunk_pages >= 1
        self.pool = pool
        self.chunk_pages = chunk_pages
        self.chunk_tokens = chunk_pages * pool.page_size
        self.trace = trace
        self._entries = OrderedDict()  # key → [page id, ...]
        self.hits = 0                  # lookups that matched >= 1 chunk
        self.misses = 0
        self.inserts = 0               # new entries registered
        self.evictions = 0             # entries reclaimed under pressure

    def __len__(self):
        return len(self._entries)

    def _keys(self, ns, prompt):
        """Chain keys for every full chunk of ``prompt``, plus the tail
        key (or None when the prompt is chunk-aligned)."""
        prompt = np.asarray(prompt, np.int32)
        n, step = len(prompt), self.chunk_tokens
        keys, parent = [], _root(ns)
        for i in range(0, n - step + 1, step):
            parent = _digest(parent, prompt[i:i + step])
            keys.append(parent)
        rem = n % step
        tail = _digest(parent, prompt[n - rem:], kind=b"T") if rem else None
        return keys, tail

    def lookup(self, ns, prompt):
        """(matched_tokens, shared_pages) for the longest cached prefix.

        ``matched_tokens`` is either a whole number of chunks (page
        aligned — prefill continues from that boundary) or the full
        prompt length (tail hit — only the first decode token remains).
        The caller owns taking its refs (``pool.share``) on the returned
        pages before anything else touches the pool.
        """
        keys, tail = self._keys(ns, prompt)
        pages, matched = [], 0
        for j, k in enumerate(keys):
            entry = self._entries.get(k)
            if entry is None:
                break
            self._entries.move_to_end(k)
            pages += entry
            matched = (j + 1) * self.chunk_tokens
        else:
            # every full chunk matched — a tail entry completes the prompt
            entry = tail and self._entries.get(tail)
            if entry:
                self._entries.move_to_end(tail)
                pages += entry
                matched = len(prompt)
        self.hits += matched > 0
        self.misses += matched == 0
        return matched, pages

    def insert(self, ns, prompt, pages):
        """Register a freshly prefilled row's pages (chunk by chunk, plus
        its partial tail). Spans already cached are touched, not
        duplicated — the cache keeps ONE physical copy per span and takes
        its own pool reference on each newly registered page."""
        keys, tail = self._keys(ns, prompt)
        for j, k in enumerate(keys):
            if k in self._entries:
                self._entries.move_to_end(k)
                continue
            span = pages[j * self.chunk_pages:(j + 1) * self.chunk_pages]
            self.pool.share(span)
            self._entries[k] = list(span)
            self.inserts += 1
        if tail is not None:
            if tail in self._entries:
                self._entries.move_to_end(tail)
            else:
                span = pages[len(keys) * self.chunk_pages:
                             self.pool.pages_needed(len(prompt))]
                self.pool.share(span)
                self._entries[tail] = list(span)
                self.inserts += 1

    def evict_for(self, pool, needed):
        """Reclaim cold entries (LRU→MRU) until ``needed`` pages are
        free, skipping entries a live row still shares. A parent chunk is
        always touched before its children, so it sits EARLIER in LRU
        order and one walk reclaims whole stale chains parent-first.
        Returns pages freed."""
        freed, stale = 0, []
        for k, pages in self._entries.items():
            if pool.free_count + freed >= needed:
                break
            if all(pool.refcount(p) == 1 for p in pages):
                stale.append(k)
                freed += len(pages)
        for k in stale:
            pages = self._entries.pop(k)
            pool.release(pages)
            self.evictions += 1
            if self.trace is not None:
                self.trace.emit("prefix_evict", pages=len(pages))
        return freed

    def clear(self, pool):
        """Drop every cache reference (pages shared by live rows just
        lose the cache's hold)."""
        for pages in self._entries.values():
            pool.release(pages)
        self._entries.clear()
