"""Live train→serve bridge: async adapter refresh without a drain.

FedSA-LoRA's split (one aggregated Ā, a personal B_i per tenant) means a
federation round only ever publishes a rank-r delta per tenant — small
enough to absorb into a *running* engine. This module is the versioned
publish/subscribe channel between ``repro.core.federation.run_rounds``
and ``ServingEngine``:

  trainer thread                          serving thread
  --------------                          --------------
  run_rounds(..., publish=feed.publish)   engine.step()
    → AdapterFeed.publish(round, tr)        → refresh phase polls feed
      (host snapshot per client,              → registry.publish(...)
       coalesced: latest round wins)          → registry.try_flip()
                                                (deferred while the
                                                 inactive buffer still
                                                 has in-flight rows)

Sequences admitted under round t keep decoding round-t weights to the
last token (token parity — no prompt is ever recomputed); sequences
admitted after the flip read round t+1 from the other buffer of the
double-buffered slot tables. ``train_and_serve`` wires the whole loop
end to end (used by ``examples/train_and_serve.py`` and
``python -m repro.launch.serve --live-refresh``).

Personal-A rounds (fedit / FedDPA registries, ``repro.kernels.sgmv``
serving path) ride the SAME machinery unchanged: ``publish`` stages
every LOCAL leaf per client — A_i tables alongside B_i tables when the
mode packs both — and the flip commits the pairs atomically per slot,
so an in-flight row can never read round-t A against round-t+1 B.
"""
from __future__ import annotations

import threading

import jax
import numpy as np


def snapshot_clients(trainables, clients=None):
    """Host-side per-client snapshot of a client-axis trainables tree:
    one ``device_get`` for the whole tree, then numpy views per client."""
    host = jax.device_get(trainables)
    n = jax.tree_util.tree_leaves(host)[0].shape[0]
    ids = range(n) if clients is None else clients
    return {int(c): jax.tree_util.tree_map(lambda x: x[c], host)
            for c in ids}


class AdapterFeed:
    """Thread-safe single-slot pub/sub channel of round publications.

    The producer (training loop) publishes ``(version, trainables)``;
    the consumer (the engine's refresh phase) polls. Unconsumed
    publications coalesce — the serving side only ever wants the newest
    round, and per-client trees from a skipped round are superseded by
    the next one (newer round wins per client).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._event = threading.Event()  # set while a publish is waiting
        self._slot = None               # (version, {cid: host tree})
        self.published = 0
        self.coalesced = 0

    def publish(self, version, trainables, clients=None):
        """Producer side — matches ``run_rounds``'s ``publish=`` callback
        signature ``(round_version, trainables)``."""
        trees = snapshot_clients(trainables, clients)
        with self._lock:
            if self._slot is not None:
                self.coalesced += 1
                _, old = self._slot
                old.update(trees)
                trees = old
            self._slot = (version, trees)
            self.published += 1
            self._event.set()

    def poll(self):
        """Consumer side: latest unconsumed ``(version, trees)`` or None."""
        with self._lock:
            slot, self._slot = self._slot, None
            self._event.clear()
        return slot

    def wait(self, timeout=None):
        """Block until a publish is pending (or ``timeout`` seconds
        elapse); returns True when one is waiting. The serving loop
        parks here when it has nothing to decode, instead of polling
        on a fixed sleep."""
        return self._event.wait(timeout)

    @property
    def pending(self):
        with self._lock:
            return self._slot is not None


def train_and_serve(cfg, acfg, fed, *, rounds=6, n_slots=4, requests=16,
                    max_new_tokens=8, batch_size=8, publish_every=1,
                    submit_every=2, seed=0, config=None, engine_kw=None,
                    log=None, max_steps=200_000, metrics=None, trace=None,
                    faults=None, robust=None):
    """Run federated training in a background thread while the foreground
    serving engine absorbs each round's adapters live.

    Builds the FedSystem (LM task on synthetic Markov-chain clients), a
    ``versioned`` registry seeded from round 0, and a paged engine
    subscribed to an ``AdapterFeed``; trickles ``requests`` heterogeneous
    prompts while ``rounds`` rounds train and publish. Returns
    ``(report, history)`` — the engine report carries version/staleness
    stats, the history is ``run_rounds``'s.

    ``metrics``/``trace`` (repro.obs) are shared across the WHOLE loop:
    the engine's serve-side histograms and ``run_rounds``'s per-round
    train metrics land in ONE ``MetricsRegistry``, and the trace
    timeline interleaves admits/retires with flips.

    ``faults`` (``repro.failures.FaultInjector``) threads the SAME
    injector through both sides: the federation loop runs its
    fault-tolerant path (with ``robust``, a ``RobustConfig``), and the
    train→serve bridge drops (``feed_drop``) or stalls (``feed_stall``,
    delivered one round late) publishes on the way to the feed.
    Exceptions raised inside the trainer thread are captured and
    re-raised here after the serving loop winds down — a dead trainer
    can no longer park the bridge forever.
    """
    from repro.core import federation
    from repro.data.synthetic import make_lm_task
    from repro.serving.config import ServingConfig
    from repro.serving.engine import ServingEngine
    from repro.serving.registry import AdapterRegistry

    log = log or (lambda *_: None)
    clients_data, _ = make_lm_task(n_clients=fed.n_clients,
                                   vocab=cfg.vocab_size, seq=32,
                                   n_train=64 * fed.n_clients, n_test=32,
                                   seed=seed)
    system = federation.build(jax.random.PRNGKey(seed), cfg, acfg, fed,
                              task="lm", lr=5e-2)
    registry = AdapterRegistry.from_system(system, n_slots, versioned=True)
    feed = AdapterFeed()
    # config wins; engine_kw (legacy loose knobs) folds on top of the
    # bridge's defaults for callers still passing a dict
    if config is None:
        config = ServingConfig(max_batch=4, max_seq=32)
    if engine_kw:
        config = config.replace(**engine_kw)
    engine = ServingEngine(cfg, system.params, acfg, registry, config,
                           feed=feed, metrics=metrics, trace=trace)

    history = {}
    trainer_errors = []
    stalled = []                       # publishes held back one round

    def publish_cb(version, trainables):
        if faults is not None:
            if faults.drops_publish(version):
                return                 # lost on the wire
            while stalled:             # a stalled round rides the next one
                v0, t0 = stalled.pop(0)
                feed.publish(v0, t0)
            if faults.stalls_publish(version):
                stalled.append((version, trainables))
                return
        feed.publish(version, trainables)

    def trainer():
        try:
            history.update(federation.run_rounds(
                system, clients_data, rounds=rounds, batch_size=batch_size,
                seed=seed, publish=publish_cb, publish_every=publish_every,
                metrics=engine.metrics, faults=faults, robust=robust,
                trace=trace))
            while stalled:             # flush a final-round stall
                v0, t0 = stalled.pop(0)
                feed.publish(v0, t0)
        except BaseException as err:   # noqa: BLE001 — re-raised on join
            trainer_errors.append(err)

    thread = threading.Thread(target=trainer, daemon=True)
    rng = np.random.default_rng(seed)
    submitted = steps = 0
    thread.start()
    while (thread.is_alive() or submitted < requests
           or not engine.scheduler.idle or feed.pending
           or registry.stats.get("pending_version") is not None):
        if trainer_errors:
            break                      # fail fast: don't serve to drain
        # pace the stream across rounds: each published version unlocks
        # its share of the request budget, so served traffic spans
        # adapter versions instead of racing ahead of the first round
        budget = requests if not thread.is_alive() else min(
            requests, max(1, (requests * (registry.version + 1))
                          // (rounds + 1)))
        if submitted < budget and steps % submit_every == 0:
            plen = int(rng.integers(4, config.max_seq - max_new_tokens))
            engine.submit(submitted % fed.n_clients,
                          rng.integers(0, cfg.vocab_size, plen),
                          max_new_tokens=max_new_tokens)
            submitted += 1
        engine.step()
        steps += 1
        if engine.scheduler.idle and submitted >= budget:
            # nothing to decode and nothing unlocked: park on the feed's
            # event until the next publish arrives (bounded so the loop
            # still notices trainer exit), instead of a fixed-sleep poll
            feed.wait(timeout=0.05)
        if steps >= max_steps:
            raise RuntimeError("train_and_serve failed to drain")
    thread.join()
    if trainer_errors:                 # surface the thread's failure here
        raise RuntimeError(
            "train_and_serve trainer thread died") from trainer_errors[0]
    report = engine.report()
    served_versions = sorted({rec["version"]
                              for rec in engine.finished.values()})
    log(f"served {report['requests']} requests across adapter versions "
        f"{served_versions} while training {rounds} rounds: "
        f"{report['flips']} flips ({report['deferred_flips']} deferred "
        f"ticks), staleness mean {report['staleness_mean']:.2f} / max "
        f"{report['staleness_max']}, {report['decode_tokens']} decode "
        f"tokens with no drain or rebuild")
    return report, history
