"""Stand-in tenant populations for serving demos, benchmarks, and tests.

A trained ``FedSystem`` is the real source of per-client adapters
(``AdapterRegistry.from_system``); these helpers fabricate the same
structure — SHARED leaves (the aggregated Ā) identical across clients,
LOCAL leaves drawn per client — without paying for federated training in
a throughput benchmark or launcher demo. ``mixed_fleet`` builds a
mode-heterogeneous population (FedSA tenants sharing Ā next to
FedIT-style tenants owning their whole adapter pair) for the generic
SGMV serving path.
"""
from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp

from repro.core.strategies import LOCAL, leaf_role


def _path_id(path):
    parts = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    return zlib.crc32("/".join(parts).encode())


def _draw_client(template, root, i, mode, scale):
    """One client's tree: LOCAL-under-``mode`` leaves redrawn per
    (client, leaf-path) — distinct even when two modules have identical
    shapes — everything else shared from the template."""
    ck = jax.random.fold_in(root, i)

    def leaf(path, x):
        if leaf_role(path, mode) != LOCAL:
            return x
        k = jax.random.fold_in(ck, _path_id(path))
        return (jax.random.normal(k, x.shape, jnp.float32)
                * scale).astype(x.dtype)

    return jax.tree_util.tree_map_with_path(leaf, template)


def synthetic_clients(template, n_clients, *, mode="fedsa", seed=0,
                      scale=0.02):
    """``n_clients`` trainables trees sharing ``template``'s SHARED
    leaves, with each LOCAL leaf drawn per (client, leaf-path)."""
    root = jax.random.PRNGKey(seed)
    return [_draw_client(template, root, i, mode, scale)
            for i in range(n_clients)]


def mixed_fleet(template, n_clients, *, modes=None, seed=0, scale=0.02):
    """A mode-heterogeneous tenant population: per-client trees whose
    personalization follows that client's OWN strategy.

    modes: per-client strategy list (default alternating
    ``fedsa``/``fedit``). A ``fedsa`` client redraws only B_i and keeps
    the template's aggregated Ā; a ``fedit`` client redraws its whole
    (A_i, B_i) pair. Serve the fleet through a registry built with
    ``mode="fedit"`` packing — per-slot A AND B tables — so the FedSA
    tenants' A slots simply hold identical copies of Ā while FedIT
    tenants' slots hold their personal A_i; ``lora_backend="sgmv"``
    routes the whole batch through the per-row-A gather. Returns
    ``(trees, modes)``.
    """
    if modes is None:
        modes = ["fedsa" if i % 2 == 0 else "fedit"
                 for i in range(n_clients)]
    assert len(modes) == n_clients, (len(modes), n_clients)
    root = jax.random.PRNGKey(seed)
    trees = [_draw_client(template, root, i, m, scale)
             for i, m in enumerate(modes)]
    return trees, list(modes)
