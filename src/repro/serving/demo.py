"""Stand-in tenant populations for serving demos, benchmarks, and tests.

A trained ``FedSystem`` is the real source of per-client adapters
(``AdapterRegistry.from_system``); these helpers fabricate the same
structure — SHARED leaves (the aggregated Ā) identical across clients,
LOCAL leaves (B_i) drawn per client — without paying for federated
training in a throughput benchmark or launcher demo.
"""
from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp

from repro.core.strategies import LOCAL, leaf_role


def _path_id(path):
    parts = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    return zlib.crc32("/".join(parts).encode())


def synthetic_clients(template, n_clients, *, mode="fedsa", seed=0,
                      scale=0.02):
    """``n_clients`` trainables trees sharing ``template``'s SHARED
    leaves, with each LOCAL leaf drawn per (client, leaf-path) — distinct
    even when two modules have identical shapes."""
    root = jax.random.PRNGKey(seed)

    def one(i):
        ck = jax.random.fold_in(root, i)

        def leaf(path, x):
            if leaf_role(path, mode) != LOCAL:
                return x
            k = jax.random.fold_in(ck, _path_id(path))
            return (jax.random.normal(k, x.shape, jnp.float32)
                    * scale).astype(x.dtype)

        return jax.tree_util.tree_map_with_path(leaf, template)

    return [one(i) for i in range(n_clients)]
