"""AdapterRegistry: dense slot tables over the hot set of per-client B_i.

The tenant population can be arbitrarily large (the cold store is a host
dict of numpy B_i trees, a few KB per client at rank 8), but a decode
batch only ever references the *hot* set admitted into ``n_slots`` dense
on-device tables. Each LOCAL adapter leaf (B under FedSA) is packed with
a slot axis so a whole mixed batch is served by one gather:

  leaf  (n_layers, r, d_out)  →  table (n_layers, n_slots, r, d_out)

SHARED/FROZEN leaves (the aggregated Ā) are stored once, verbatim — the
FedSA invariant that makes the grouped kernel cheap. Admission is LRU
with pinning: slots referenced by in-flight sequences are never evicted;
``acquire`` returns ``None`` when every slot is pinned (the scheduler
then leaves the request queued).
"""
from __future__ import annotations

from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import LOCAL, leaf_role


def _pack_axis(leaf_ndim):
    """Slot-axis position: just before the last two (matmul) dims, so a
    per-row gather yields a leading batch axis under the layer scan."""
    return max(0, leaf_ndim - 2)


def gather_adapters(tables, local, slot_ids):
    """Materialize the per-row adapter tree for a batch (jit-safe).

    tables: registry tree (packed LOCAL tables + shared leaves);
    local: matching pytree of python bools; slot_ids: (B,) int32.
    LOCAL leaves gain a per-row axis: (n, n_slots, r, d) → (n, B, r, d).
    """
    return jax.tree_util.tree_map(
        lambda leaf, loc: jnp.take(leaf, slot_ids, axis=_pack_axis(
            leaf.ndim - 1)) if loc else leaf,
        tables, local)


class AdapterRegistry:
    """LRU admission of per-client local adapters into dense slot tables."""

    def __init__(self, template, n_slots, *, mode="fedsa"):
        """template: ONE client's trainables tree (e.g.
        ``{"adapters": ...}`` without the client axis); its SHARED leaves
        seed the batch-global Ā."""
        if mode != "fedsa":
            raise NotImplementedError(
                "grouped serving relies on the FedSA invariant (batch-"
                f"global Ā, per-client B); mode={mode!r} has per-client A")
        self.mode = mode
        self.n_slots = n_slots
        flat, self._treedef = jax.tree_util.tree_flatten_with_path(template)
        self._local = [leaf_role(path, mode) == LOCAL for path, _ in flat]
        self._leaves = []
        for (path, leaf), loc in zip(flat, self._local):
            if loc:
                name = (str(path[-1].key) if hasattr(path[-1], "key")
                        else "")
                if name != "B":
                    raise NotImplementedError(
                        "grouped serving packs LoRA B matrices only; "
                        f"LOCAL leaf {name!r} (e.g. VeRA's b vector) has "
                        "no per-row gather path in lora_delta")
                shape = (leaf.shape[:_pack_axis(leaf.ndim)] + (n_slots,)
                         + leaf.shape[_pack_axis(leaf.ndim):])
                self._leaves.append(jnp.zeros(shape, leaf.dtype))
            else:
                self._leaves.append(jnp.asarray(leaf))
        self._store = {}                    # client_id → [local leaves] (np)
        self._lru = OrderedDict()           # client_id → slot (LRU order)
        self._free = list(range(n_slots))[::-1]
        self._pins = [0] * n_slots
        self.hits = self.misses = self.evictions = 0

    # -- cold store ---------------------------------------------------------
    def ingest(self, client_id, client_tree):
        """Register a client's trained trainables tree (host-side copy of
        its LOCAL leaves only — the per-tenant cold store)."""
        flat = jax.tree_util.tree_leaves(client_tree)
        assert len(flat) == len(self._local), "tree structure mismatch"
        self._store[client_id] = [
            np.asarray(leaf) for leaf, loc in zip(flat, self._local) if loc]

    @classmethod
    def from_system(cls, system, n_slots, *, clients=None):
        """Build from a trained ``FedSystem``: splits the client axis off
        ``system.trainables`` and ingests every (or the given) client."""
        tr = system.trainables
        n_clients = system.fed.n_clients
        template = jax.tree_util.tree_map(lambda x: x[0], tr)
        reg = cls(template, n_slots, mode=system.acfg.mode)
        for c in (range(n_clients) if clients is None else clients):
            reg.ingest(c, jax.tree_util.tree_map(lambda x: x[c], tr))
        return reg

    # -- admission ----------------------------------------------------------
    def acquire(self, client_id, *, pin=True):
        """Slot for ``client_id``, admitting (and LRU-evicting) on miss.
        Returns None when no unpinned slot is available."""
        if client_id in self._lru:
            self.hits += 1
            self._lru.move_to_end(client_id)
            slot = self._lru[client_id]
        else:
            self.misses += 1
            if client_id not in self._store:
                raise KeyError(f"client {client_id} was never ingested")
            if self._free:
                slot = self._free.pop()
            else:
                victim = next((c for c, s in self._lru.items()
                               if self._pins[s] == 0), None)
                if victim is None:
                    return None
                slot = self._lru.pop(victim)
                self.evictions += 1
            self._write_slot(slot, client_id)
            self._lru[client_id] = slot
        if pin:
            self._pins[slot] += 1
        return slot

    def release(self, client_id):
        slot = self._lru[client_id]
        assert self._pins[slot] > 0
        self._pins[slot] -= 1

    def _write_slot(self, slot, client_id):
        stored = iter(self._store[client_id])
        for i, loc in enumerate(self._local):
            if loc:
                table = self._leaves[i]
                idx = ((slice(None),) * _pack_axis(table.ndim - 1)
                       + (slot,))
                self._leaves[i] = table.at[idx].set(
                    jnp.asarray(next(stored), table.dtype))

    # -- views --------------------------------------------------------------
    @property
    def tables(self):
        """Registry tree: packed LOCAL tables + shared leaves (pass to
        ``gather_adapters`` together with ``local_tree``)."""
        return jax.tree_util.tree_unflatten(self._treedef, self._leaves)

    @property
    def local_tree(self):
        return jax.tree_util.tree_unflatten(self._treedef, self._local)

    def gather(self, slot_ids):
        """Per-row adapter tree for a batch of slot ids (eager helper)."""
        return gather_adapters(self.tables, self.local_tree,
                               jnp.asarray(slot_ids, jnp.int32))

    @property
    def stats(self):
        total = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": self.hits / total if total else 0.0,
                "resident": len(self._lru), "n_slots": self.n_slots,
                "clients": len(self._store)}
