"""AdapterRegistry: dense slot tables over the hot set of per-client
adapter matrices.

The tenant population can be arbitrarily large — below the ``n_slots``
dense on-device tables sits a hierarchical ``AdapterStore``
(``repro.serving.store``): a pinned-host-RAM ring of preformatted
slot-shaped numpy arrays, then a cold npz store. ``acquire`` therefore
distinguishes three outcomes: an HBM hit (slot already resident), a
host-hit (one device transfer per leaf), and a cold miss (synchronous
npz load — the only stalling path, counted and traced as ``tier_miss``).
Eviction demotes down a tier instead of discarding, and ``prefetch``
promotes upcoming clients host-ward on a background thread. A decode
batch only ever references the *hot* set admitted into the tables. Each LOCAL adapter *matrix* leaf is
packed with a slot axis so a whole mixed batch is served by one gather:

  B leaf  (n_layers, r, d_out)  →  table (n_layers, n_slots, r, d_out)
  A leaf  (n_layers, d_in, r)   →  table (n_layers, n_slots, d_in, r)

Which leaves are LOCAL depends on the federation strategy
(``core.strategies``): under FedSA only B_i is per-client — the
aggregated Ā is SHARED and stored once, verbatim, the invariant that
makes the ``bgmv`` grouped kernel cheap. Under FedIT-style plain LoRA
(``mode="fedit"``) and FedDPA's personal adapters BOTH matrices are
per-client, so A leaves get their own slot tables paired with the B
tables (one slot index covers the pair — a client's A_i and B_i always
travel together through admission, eviction, and the versioned flip)
and serving routes through the generic per-row-A gather (SGMV,
``repro.kernels.sgmv``). A mode-heterogeneous fleet (FedSA + FedIT
tenants in one registry) uses ``mode="fedit"`` packing: the FedSA
tenants' A_i are simply identical copies of Ā. VeRA's LOCAL leaves are
*vectors* (no per-row gather path in ``lora_delta``) and are rejected.

Admission is LRU with pinning: slots referenced by in-flight sequences
are never evicted; ``acquire`` raises ``RuntimeError`` when every slot
is pinned (the scheduler then leaves the request queued).

Versioned mode (``versioned=True``) double-buffers every table for the
live train→serve bridge (``repro.serving.refresh``): LOCAL tables double
their slot axis (buffer b of slot s lives at index ``b*n_slots + s``)
and SHARED leaves gain a 2-wide version axis at the same pack position,
so one version-indexed gather serves a mixed batch whose rows span two
federation rounds. ``publish`` stages a round's post-aggregation weights
host-side; ``try_flip`` commits them into the *inactive* buffer — and is
deferred while any in-flight sequence still reads that buffer, so tokens
of already-admitted sequences never change mid-generation.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import LOCAL, leaf_role
from repro.serving.store import AdapterStore, Prefetcher


def _pack_axis(leaf_ndim):
    """Slot-axis position: just before the last two (matmul) dims, so a
    per-row gather yields a leading batch axis under the layer scan."""
    return max(0, leaf_ndim - 2)


def gather_adapters(tables, local, slot_ids):
    """Materialize the per-row adapter tree for a batch (jit-safe).

    tables: registry tree (packed LOCAL tables + shared leaves);
    local: matching pytree of python bools; slot_ids: (B,) int32.
    LOCAL leaves gain a per-row axis: (n, n_slots, r, d) → (n, B, r, d).
    Under per-client-A packing (fedit/feddpa) the A tables gather the
    same way — (n, n_slots, d, r) → (n, B, d, r) — and ``lora_delta``
    runs the shrink as a batched matmul (the SGMV path).
    """
    return jax.tree_util.tree_map(
        lambda leaf, loc: jnp.take(leaf, slot_ids, axis=_pack_axis(
            leaf.ndim - 1)) if loc else leaf,
        tables, local)


def gather_adapters_versioned(tables, local, slot_ids, buf_ids, stride):
    """Version-indexed per-row gather for double-buffered registries.

    LOCAL tables index the doubled slot axis at ``buf*stride + slot``;
    SHARED leaves index their 2-wide version axis per row, so the
    aggregated Ā ALSO gains a per-row axis — ``lora_delta`` handles the
    resulting (B, d_in, r) A as a batched matmul, letting one decode
    batch mix rows admitted under different federation rounds.
    ``stride`` is the registry's ``slot_stride`` (``n_slots + 1`` — the
    extra index is the all-zeros degraded slot, see below).
    """
    eff = buf_ids * stride + slot_ids
    return jax.tree_util.tree_map(
        lambda leaf, loc: jnp.take(leaf, eff if loc else buf_ids,
                                   axis=_pack_axis(leaf.ndim - 1)),
        tables, local)


class AdapterRegistry:
    """LRU admission of per-client local adapters into dense slot tables."""

    def __init__(self, template, n_slots, *, mode="fedsa", versioned=False,
                 flip_patience=None, validate_publish=False,
                 host_ring_slots=None, cold_dir=None):
        """template: ONE client's trainables tree (e.g.
        ``{"adapters": ...}`` without the client axis); its SHARED leaves
        seed the batch-global Ā.

        flip_patience: after this many CONSECUTIVE deferred ``try_flip``
        attempts on the same pending publish, the stage is dropped and
        serving stays on the last-good tables (a ``rollback`` event with
        ``reason="flip_timeout"``). None = wait forever (the default —
        under normal retirement the blocker always drains).
        validate_publish: reject non-finite staged weights at ``publish``
        time — per-client (that client's stage is skipped, the rest of
        the round lands) and for the SHARED leaves (the whole publish is
        refused: a poisoned Ā must never reach the flip).
        host_ring_slots / cold_dir: tiering bounds of the underlying
        ``AdapterStore`` — ring capacity in adapters (None = unbounded
        host tier, the pre-tiering behavior) and the cold npz directory
        (None = in-memory cold tier). See ``repro.serving.store``.
        """
        self.mode = mode
        self.n_slots = n_slots
        # slot axis stride: one extra, never-written index per buffer —
        # the DEGRADED slot. Its table entries stay all-zero, so a row
        # gathered at ``degraded_slot`` sees a zero LoRA delta and serves
        # the frozen base model (graceful fallback when no real slot can
        # be pinned; see docs/robustness.md).
        self.slot_stride = n_slots + 1
        self.versioned = versioned
        self.n_buffers = 2 if versioned else 1
        self.flip_patience = flip_patience
        self.validate_publish = validate_publish
        self._defer_streak = 0
        self.flip_timeouts = 0
        self.publish_rejects = 0
        flat, self._treedef = jax.tree_util.tree_flatten_with_path(template)
        self._local = [leaf_role(path, mode) == LOCAL for path, _ in flat]
        if not any(self._local):
            raise ValueError(
                f"mode={mode!r} keeps no adapter leaf client-local — "
                "every tenant serves identical weights, there is nothing "
                "to personalize (fedavg/ffa aggregate or freeze both "
                "matrices)")
        self.has_local_A = False
        self._leaves = []
        formats = []                    # table dtype per LOCAL leaf
        for (path, leaf), loc in zip(flat, self._local):
            ax = _pack_axis(leaf.ndim)
            if loc:
                name = (str(path[-1].key) if hasattr(path[-1], "key")
                        else "")
                if name not in ("A", "B"):
                    raise NotImplementedError(
                        "grouped serving packs LoRA A/B matrices only; "
                        f"LOCAL leaf {name!r} (e.g. VeRA's b vector) has "
                        "no per-row gather path in lora_delta")
                self.has_local_A |= name == "A"
                shape = (leaf.shape[:ax]
                         + (self.n_buffers * self.slot_stride,)
                         + leaf.shape[ax:])
                self._leaves.append(jnp.zeros(shape, leaf.dtype))
                formats.append(np.dtype(leaf.dtype))
            elif versioned:
                leaf = jnp.asarray(leaf)
                self._leaves.append(jnp.stack([leaf, leaf], axis=ax))
            else:
                self._leaves.append(jnp.asarray(leaf))
        # host-side tiers under the HBM tables: preformatted host ring +
        # cold npz store (dict-compatible — cid → [local leaves])
        self._store = AdapterStore(host_ring_slots=host_ring_slots,
                                   cold_dir=cold_dir, formats=formats)
        self._local_idx = [i for i, loc in enumerate(self._local) if loc]
        self._slot_writer = None            # lazy fused jitted writer
        self._prefetcher = None             # lazy background promoter
        self._client_ver = {}               # client_id → committed version
        self._seq = 0                       # monotone cold-store write stamp
        self._store_seq = {}                # client_id → stamp at last write
        self._lru = OrderedDict()           # client_id → slot (LRU order)
        self._free = list(range(n_slots))[::-1]
        self._pins = [0] * n_slots
        # per-(buffer, slot) record of what was last written there
        self._slot_tag = [[None] * n_slots for _ in range(self.n_buffers)]
        # in-flight sequence counts per buffer (scheduler retain/release)
        self._buf_rows = [0] * self.n_buffers
        self.active_buf = 0                 # buffer new admissions read
        self.version = 0                    # round of the active buffer
        self._pending = None                # staged publish awaiting flip
        self.hits = self.misses = self.evictions = 0
        # admission-path tier accounting: an HBM miss is either served
        # from the host ring (host-hit) or stalls on a cold npz load
        self.tier_host_hits = self.tier_cold_misses = 0
        self.prefetches = 0
        self.tier_prestages = 0             # host→HBM pre-stages (free slot)
        self._tier_seen = {}                # store counter → obs diff base
        # exact per-acquire wall samples, (tier, seconds) — bounded so a
        # long-lived registry stays O(1); the tiering bench reads p99
        # off these instead of log-bucketed histograms (bucket error is
        # too coarse for a 2× latency gate)
        self._admit_samples = deque(maxlen=4096)
        self.flips = self.deferred_flips = self.publishes = 0
        # observability hooks (repro.obs) — the engine wires these to
        # its own TraceLog / MetricsRegistry; both optional
        self.trace = None
        self.metrics = None

    # -- cold store ---------------------------------------------------------
    def ingest(self, client_id, client_tree):
        """Register a client's trained trainables tree (host-side copy of
        its LOCAL leaves only — the per-tenant cold store). For updates
        while sequences are in flight use ``publish`` instead: a pinned
        resident slot keeps serving its admitted weights until it is
        unpinned (the next unpinned ``acquire`` refreshes it)."""
        self._store[client_id] = self._local_leaves(client_tree)
        self._client_ver[client_id] = self.version
        self._seq += 1
        self._store_seq[client_id] = self._seq

    def _local_leaves(self, client_tree):
        flat = jax.tree_util.tree_leaves(client_tree)
        assert len(flat) == len(self._local), "tree structure mismatch"
        return [np.asarray(leaf)
                for leaf, loc in zip(flat, self._local) if loc]

    def _shared_leaves(self, client_tree):
        flat = jax.tree_util.tree_leaves(client_tree)
        assert len(flat) == len(self._local), "tree structure mismatch"
        return [np.asarray(leaf)
                for leaf, loc in zip(flat, self._local) if not loc]

    @classmethod
    def from_system(cls, system, n_slots, *, clients=None, versioned=False,
                    mode=None, **kw):
        """Build from a trained ``FedSystem``: splits the client axis off
        ``system.trainables`` and ingests every (or the given) client.
        ``mode`` overrides the system's aggregation mode (e.g. pack a
        FedSA fleet into ``fedit`` A+B tables for a mixed deployment);
        extra kwargs (``host_ring_slots``, ``cold_dir``, ...) forward to
        the constructor."""
        tr = system.trainables
        n_clients = system.fed.n_clients
        template = jax.tree_util.tree_map(lambda x: x[0], tr)
        reg = cls(template, n_slots,
                  mode=system.acfg.mode if mode is None else mode,
                  versioned=versioned, **kw)
        for c in (range(n_clients) if clients is None else clients):
            reg.ingest(c, jax.tree_util.tree_map(lambda x: x[c], tr))
        return reg

    # -- admission ----------------------------------------------------------
    def acquire(self, client_id, *, pin=True):
        """Slot for ``client_id``, admitting (and LRU-evicting) on miss.

        Raises ``RuntimeError`` when admission would need to evict a
        pinned slot (every slot referenced by an in-flight sequence); a
        failed acquire leaves the LRU order and counters untouched, so
        the scheduler can retry the same request next tick.

        Tier accounting: a resident slot is an HBM hit; a miss is served
        from the host ring (host-hit — one device transfer per leaf) or
        stalls on a cold npz load (cold miss, traced as ``tier_miss``).
        Each successful acquire books one (tier, wall-seconds) sample
        into ``admission_samples``.
        """
        t0 = time.perf_counter()
        resident = client_id in self._lru
        tier = "hbm" if resident else self._store.tier_of(client_id)
        slot = self._acquire_slot(client_id, pin=pin)
        if not resident:
            if tier == "cold":
                self.tier_cold_misses += 1
                if self.trace is not None:
                    self.trace.emit("tier_miss", client=client_id,
                                    tier="cold")
            else:
                self.tier_host_hits += 1
        self._admit_samples.append((tier, time.perf_counter() - t0))
        self._sync_tier_metrics()
        return slot

    def _acquire_slot(self, client_id, *, pin):
        if client_id in self._lru:
            slot = self._lru[client_id]
            if (self._pins[slot] == 0
                    and self._slot_tag[self.active_buf][slot]
                    != self._tag_of(client_id)):
                # resident but stale (a re-ingest or publish landed since
                # the slot was written): refresh the active half — safe
                # because an unpinned slot has no in-flight reader
                self._write_slot(slot, client_id, self.active_buf)
            self.hits += 1
            self._lru.move_to_end(client_id)
            # recency flows DOWN the hierarchy: an HBM hit also bumps
            # the client's host-ring entry, so a hot resident tenant
            # never ages out of the ring and its eventual eviction
            # lands host-warm instead of cold-stalling on re-admission
            self._store.touch(client_id)
        else:
            if client_id not in self._store:
                raise KeyError(f"client {client_id} was never ingested")
            if self._free:
                slot = self._free.pop()
            else:
                victim = next((c for c, s in self._lru.items()
                               if self._pins[s] == 0), None)
                if victim is None:
                    raise RuntimeError(
                        f"all {self.n_slots} adapter slots are pinned by "
                        "in-flight sequences; cannot admit client "
                        f"{client_id} until one retires")
                slot = self._lru.pop(victim)
                self.evictions += 1
                # demote, don't discard: the victim's bytes stay warm in
                # the host ring (MRU touch) — or stay cold if ring churn
                # already demoted them; either way re-admission never
                # re-ingests from scratch
                self._store.touch(victim)
                if self.trace is not None:
                    self.trace.emit("eviction", client=victim, slot=slot)
                if self.metrics is not None:
                    self.metrics.counter(
                        "repro_adapter_evictions_total",
                        "LRU slot evictions").inc()
            self.misses += 1
            self._write_slot(slot, client_id, self.active_buf)
            self._lru[client_id] = slot
        if pin:
            self._pins[slot] += 1
        return slot

    def release(self, client_id):
        """Unpin one reference to ``client_id``'s slot. Unknown or
        never-pinned clients are a no-op (retire paths may race a
        registry that already evicted an unpinned tenant)."""
        slot = self._lru.get(client_id)
        if slot is None or self._pins[slot] == 0:
            return
        self._pins[slot] -= 1

    def _tag_of(self, client_id):
        """Identity of a client's CURRENT cold-store content: the write
        stamp disambiguates re-ingests within one version (a version-only
        tag would treat them as already-served)."""
        return (client_id, self._store_seq.get(client_id, 0))

    def adapter_tag(self, client_id):
        """Public adapter-bytes identity for ``client_id`` — the prefix
        cache's namespace key. Changes whenever the bytes a NEW admission
        would decode under change (ingest, or a publish once its flip
        commits), so KV cached under old bytes can never be reused."""
        return self._tag_of(client_id)

    def _write_slot(self, slot, client_id, buf=0):
        """Commit a client's stored leaves into table position
        ``buf*stride + slot`` as ONE jitted, donated device call.

        The host ring keeps leaves preformatted (contiguous, table
        dtype), so admission pays a single dispatch/transfer instead of
        one eager ``.at[].set`` round-trip per LOCAL leaf — the host-hit
        fast path the tiering bench gates on. Donation recycles the old
        table buffers; safe because the engine re-reads ``.tables``
        every call and never caches the arrays across a host sync."""
        if self._slot_writer is None:
            packs = [_pack_axis(self._leaves[i].ndim - 1)
                     for i in self._local_idx]

            def write(tables, leaves, pos):
                out = []
                for table, leaf, ax in zip(tables, leaves, packs):
                    idx = (slice(None),) * ax + (pos,)
                    out.append(table.at[idx].set(
                        jnp.asarray(leaf, table.dtype)))
                return out

            self._slot_writer = jax.jit(write, donate_argnums=0)
        new = self._slot_writer([self._leaves[i] for i in self._local_idx],
                                list(self._store[client_id]),
                                np.int32(buf * self.slot_stride + slot))
        for i, table in zip(self._local_idx, new):
            self._leaves[i] = table
        self._slot_tag[buf][slot] = self._tag_of(client_id)

    # -- tiering / prefetch (repro.serving.store) ---------------------------
    def prefetch(self, client_id):
        """Stage a queued client one tier up before its admission.

        Host-warm client + a FREE slot → pre-stage straight into HBM
        now (``tier_prestage``): the slot write is one async jitted
        dispatch that overlaps the device scan, so the later ``acquire``
        is a resident hit with zero admission stall. Cold client → queue
        a background host-ward promotion on the prefetcher thread (the
        next lookahead pass then prestages it host→HBM). No-op (False)
        for HBM-resident, unknown, already-queued, or host-warm-but-no-
        free-slot clients — prestaging never evicts."""
        if client_id in self._lru:
            return False
        tier = self._store.tier_of(client_id)
        if tier == "host" and self._free:
            slot = self._free.pop()
            self._write_slot(slot, client_id, self.active_buf)
            self._lru[client_id] = slot
            self.tier_prestages += 1
            if self.trace is not None:
                self.trace.emit("tier_prestage", client=client_id,
                                slot=slot)
            if self.metrics is not None:
                self.metrics.counter(
                    "repro_adapter_tier_prestage_total",
                    "host→HBM pre-stages into a free slot").inc()
            return True
        if tier != "cold":
            return False
        if self._prefetcher is None:
            self._prefetcher = Prefetcher(self._store)
        if not self._prefetcher.request(client_id):
            return False
        self.prefetches += 1
        if self.trace is not None:
            self.trace.emit("adapter_prefetch", client=client_id)
        if self.metrics is not None:
            self.metrics.counter("repro_adapter_prefetch_total",
                                 "background host-ward promotions "
                                 "issued").inc()
        return True

    def drain_prefetch(self, timeout=5.0):
        """Block until every queued prefetch finished (tests/benches —
        the serving path never waits on the prefetcher)."""
        if self._prefetcher is None:
            return True
        return self._prefetcher.drain(timeout)

    def configure_tiers(self, *, host_ring_slots=None, cold_dir=None):
        """Re-tier the store in place (entries migrate, LRU order and
        bytes preserved) — how an engine applies ``ServingConfig``
        tiering knobs to a registry built with the unbounded default."""
        store = self._store
        if (store.host_ring_slots == host_ring_slots
                and store.cold_dir == cold_dir):
            return
        if self._prefetcher is not None:
            self._prefetcher.stop()
            self._prefetcher = None
        new = AdapterStore(host_ring_slots=host_ring_slots,
                           cold_dir=cold_dir, formats=store.formats)
        new.migrate_from(store)
        self._store = new

    def _sync_tier_metrics(self):
        """Mirror the store's tier counters into obs counters by diff —
        promotions/demotions happen on the prefetcher thread, so the
        registry books them from the main thread rather than sharing
        Counter.inc across threads."""
        if self.metrics is None:
            return
        counts = self._store.counters
        counts["host_hits"] = self.tier_host_hits
        counts["cold_misses"] = self.tier_cold_misses
        names = {
            "host_hits": ("repro_adapter_tier_host_hits_total",
                          "HBM misses served from the host ring"),
            "cold_misses": ("repro_adapter_tier_cold_misses_total",
                            "HBM misses that stalled on the cold store"),
            "promotions": ("repro_adapter_tier_promotions_total",
                           "cold → host-ring promotions"),
            "demotions": ("repro_adapter_tier_demotions_total",
                          "host-ring → cold demotions"),
        }
        for key, (name, help_) in names.items():
            d = counts[key] - self._tier_seen.get(key, 0)
            if d > 0:
                self.metrics.counter(name, help_).inc(d)
            self._tier_seen[key] = counts[key]

    @property
    def admission_samples(self):
        """Recent (tier, wall-seconds) acquire samples, oldest first —
        exact tail-latency data for the tiering bench (tier is "hbm",
        "host", or "cold")."""
        return list(self._admit_samples)

    def reset_tier_stats(self):
        """Zero admission/tier counters and latency samples (e.g. after
        a warm-up pass); obs counters stay lifetime-monotonic."""
        self.hits = self.misses = self.evictions = 0
        self.tier_host_hits = self.tier_cold_misses = 0
        self.prefetches = 0
        self.tier_prestages = 0
        self._admit_samples.clear()
        self._store.reset_counters()
        self._tier_seen = {}

    @property
    def degraded_slot(self):
        """The reserved all-zeros slot index (``n_slots``): rows gathered
        here see a zero LoRA delta in every buffer — i.e. the frozen base
        model. Never written, never pinned, never evicted."""
        return self.n_slots

    # -- versioned refresh (repro.serving.refresh) --------------------------
    def retain_buffer(self):
        """Record one in-flight sequence on the active buffer (called by
        the scheduler at admission); returns the buffer id to stamp on
        the sequence."""
        self._buf_rows[self.active_buf] += 1
        return self.active_buf

    def release_buffer(self, buf):
        """Drop one in-flight reference (called at retirement) — the
        inactive buffer becomes flippable once its count reaches zero."""
        if self._buf_rows[buf] > 0:
            self._buf_rows[buf] -= 1

    def publish(self, version, client_trees, *, shared_from=None):
        """Stage a federation round's post-aggregation weights.

        client_trees: ``{client_id: trainables tree}`` (host or device);
        the SHARED leaves (aggregated Ā — identical across clients under
        FedSA; absent under pure-personal modes like fedit, where the
        A_i ride the per-client LOCAL tables instead) are taken from
        ``shared_from`` or any client tree. The
        stage is host-side; device writes happen at ``try_flip``, which
        this attempts immediately. Returns True when the flip committed,
        False when it was deferred behind in-flight sequences (the
        engine's refresh phase retries each tick). Stale versions
        (≤ the committed or already-staged version) are ignored.
        """
        if not self.versioned:
            raise RuntimeError(
                "publish requires a double-buffered registry "
                "(AdapterRegistry(..., versioned=True))")
        if version <= self.version:
            return False
        if self._pending is not None and version <= self._pending["version"]:
            return False
        src = shared_from
        if src is None:
            if not client_trees:
                raise ValueError("publish needs client trees (or "
                                 "shared_from) to stage")
            src = next(iter(client_trees.values()))
        staged = {cid: self._local_leaves(t)
                  for cid, t in client_trees.items()}
        if self.validate_publish:
            shared = self._shared_leaves(src)
            if not all(np.isfinite(leaf).all() for leaf in shared):
                # a poisoned Ā would reach EVERY tenant at the flip:
                # refuse the whole publish, keep serving last-good
                self.publish_rejects += 1
                if self.trace is not None:
                    self.trace.emit("rollback", reason="nonfinite_shared",
                                    version=version)
                return False
            bad = [cid for cid, leaves in staged.items()
                   if not all(np.isfinite(leaf).all() for leaf in leaves)]
            for cid in bad:
                del staged[cid]
                self.publish_rejects += 1
                if self.trace is not None:
                    self.trace.emit("update_rejected", round=version,
                                    client=cid, reason="nonfinite_publish")
            # an all-rejected round still stages: the (validated) shared
            # Ā flip is independent of the per-client stages
        # publish→flip latency is measured from the OLDEST unflipped
        # stage: a coalesced publish inherits the pending stamp
        staged_t = time.perf_counter()
        if self._pending is not None:       # coalesce: newer round wins
            merged = self._pending["clients"]
            merged.update(staged)
            staged = merged
            staged_t = self._pending["staged_t"]
        self._pending = {"version": version, "clients": staged,
                         "shared": self._shared_leaves(src),
                         "staged_t": staged_t}
        self.publishes += 1
        if self.metrics is not None:
            self.metrics.counter("repro_adapter_publishes_total",
                                 "federation rounds staged").inc()
        return self.try_flip()

    def try_flip(self):
        """Commit the staged publish into the inactive buffer and make it
        active for new admissions. Deferred (returns False) while any
        in-flight sequence still reads that buffer — their tokens must
        not change mid-generation."""
        if not self.versioned or self._pending is None:
            return False
        target = 1 - self.active_buf
        if self._buf_rows[target] > 0:
            self.deferred_flips += 1
            self._defer_streak += 1
            if self.trace is not None:
                self.trace.emit("deferred_flip",
                                version=self._pending["version"],
                                blocking_rows=self._buf_rows[target])
            if (self.flip_patience is not None
                    and self._defer_streak >= self.flip_patience):
                # bounded retry: the blocker has outlived our patience —
                # drop the stage and keep serving the last-good tables
                # (the NEXT publish gets a fresh stage and fresh streak)
                dropped = self._pending["version"]
                self._pending = None
                self._defer_streak = 0
                self.flip_timeouts += 1
                if self.trace is not None:
                    self.trace.emit("rollback", reason="flip_timeout",
                                    version=dropped)
            return False
        pend = self._pending
        shared = iter(pend["shared"])
        for i, loc in enumerate(self._local):
            if not loc:
                leaf = self._leaves[i]
                ax = _pack_axis(leaf.ndim - 1)
                idx = (slice(None),) * ax + (target,)
                self._leaves[i] = leaf.at[idx].set(
                    jnp.asarray(next(shared), leaf.dtype))
        for cid, leaves in pend["clients"].items():
            self._store[cid] = leaves
            self._client_ver[cid] = pend["version"]
            self._seq += 1
            self._store_seq[cid] = self._seq
        for cid, slot in self._lru.items():
            if self._slot_tag[target][slot] != self._tag_of(cid):
                self._write_slot(slot, cid, target)
        self.active_buf = target
        self.version = pend["version"]
        self.flips += 1
        self._pending = None
        self._defer_streak = 0
        if self.trace is not None:
            self.trace.emit("flip", version=self.version)
        if self.metrics is not None:
            self.metrics.counter("repro_adapter_flips_total",
                                 "committed buffer flips").inc()
            self.metrics.histogram(
                "repro_adapter_publish_to_flip_seconds",
                "stage→commit latency of a published round").observe(
                time.perf_counter() - pend["staged_t"])
        return True

    # -- views --------------------------------------------------------------
    @property
    def tables(self):
        """Registry tree: packed LOCAL tables + shared leaves (pass to
        ``gather_adapters`` together with ``local_tree``)."""
        return jax.tree_util.tree_unflatten(self._treedef, self._leaves)

    @property
    def local_tree(self):
        return jax.tree_util.tree_unflatten(self._treedef, self._local)

    def place(self, mesh, spec_tree):
        """Commit the packed tables to the mesh (sharded serving).

        ``spec_tree`` mirrors ``.tables`` (build it with
        ``repro.serving.sharded.shard_tables``): slot tables replicated
        over "data" — any decode row may gather any slot — and
        column-parallel B tables split over "model". Resets the lazy
        slot writer so its donated jit retraces against the committed
        shardings; eager ``at[].set`` updates (flip commits, slot
        writes) propagate the placement, so one call at engine
        construction is enough."""
        from jax.sharding import NamedSharding
        specs = jax.tree_util.tree_leaves(
            spec_tree, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        assert len(specs) == len(self._leaves)
        self._leaves = [jax.device_put(leaf, NamedSharding(mesh, spec))
                        for leaf, spec in zip(self._leaves, specs)]
        self._slot_writer = None

    def gather(self, slot_ids, buf_ids=None):
        """Per-row adapter tree for a batch of slot ids (eager helper).
        Versioned registries default every row to the active buffer."""
        slot_ids = jnp.asarray(slot_ids, jnp.int32)
        if not self.versioned:
            return gather_adapters(self.tables, self.local_tree, slot_ids)
        if buf_ids is None:
            buf_ids = jnp.full(slot_ids.shape, self.active_buf, jnp.int32)
        return gather_adapters_versioned(
            self.tables, self.local_tree, slot_ids,
            jnp.asarray(buf_ids, jnp.int32), self.slot_stride)

    @property
    def stats(self):
        total = self.hits + self.misses
        pinned = sum(1 for p in self._pins if p > 0)
        tier_total = self.tier_host_hits + self.tier_cold_misses
        out = {"hits": self.hits, "misses": self.misses,
               "evictions": self.evictions,
               "hit_rate": self.hits / total if total else 0.0,
               "resident": len(self._lru), "n_slots": self.n_slots,
               # slot-state breakdown: pinned (in-flight readers),
               # free (never written), the reserved degraded zero slot
               "pinned_slots": pinned,
               "unpinned_resident": len(self._lru) - sum(
                   1 for c, s in self._lru.items() if self._pins[s] > 0),
               "free_slots": len(self._free),
               "degraded_slots": 1,
               # tiering (repro.serving.store): occupancy per tier and
               # the admission-path split of HBM misses
               "tier_occupancy": {"hbm": len(self._lru),
                                  "host": self._store.host_count,
                                  "cold": self._store.cold_count},
               "host_ring_slots": self._store.host_ring_slots,
               "tier_host_hits": self.tier_host_hits,
               "tier_cold_misses": self.tier_cold_misses,
               "host_hit_rate": (self.tier_host_hits / tier_total
                                 if tier_total else None),
               "promotions": self._store.promotions,
               "demotions": self._store.demotions,
               "prefetches": self.prefetches,
               "tier_prestages": self.tier_prestages,
               "mode": self.mode, "local_A": self.has_local_A,
               "clients": len(self._store), "version": self.version,
               "flips": self.flips, "deferred_flips": self.deferred_flips,
               "publishes": self.publishes,
               "flip_timeouts": self.flip_timeouts,
               "publish_rejects": self.publish_rejects}
        if self.versioned:
            out["pending_version"] = (self._pending["version"]
                                      if self._pending else None)
            out["blocking_rows"] = self._buf_rows[1 - self.active_buf]
            # per-tenant staleness of the COLD store vs the committed
            # round (in-flight row staleness is tracked by the engine)
            out["tenant_versions"] = dict(self._client_ver)
        return out
