"""Mesh-sharded serving: placement rules + the collective flip check.

The FedSA-LoRA structure is what makes the engine shardable at all: the
aggregated Ā is batch-global (replicate, or tensor-shard with the base
weights), while everything per-row — decode tokens, positions, slot/buf
ids, block tables, and the KV page pool behind them — splits cleanly
along a batch axis. This module owns the mapping:

  base params        ``param_specs`` (Megatron TP over "model"), divisi-
                     bility-sanitized per leaf (a 2-way CPU mesh cannot
                     16-way-shard anything, so non-dividing dims fall
                     back to replicated)
  adapter tables     ``serving_table_specs``: REPLICATED over "data"
                     (any row may gather any slot), col-parallel B
                     tables sharded over "model"
  KV page pool       ``paged_cache_specs``: page axis over "data", KV
                     heads over "model" when divisible; the dense
                     fallback layout reuses the trainer's
                     ``cache_specs`` (batch over "data")
  per-step rows      tokens / positions / slot ids / buf ids / block
                     tables constrained to P("data", ...) inside the
                     jitted steps — the block table rides in as a
                     per-shard operand, so each data shard reads only
                     its own rows' page indirections

The engine keeps its single-controller structure: one registry, one
scheduler, one ``step()`` loop; GSPMD partitions every jitted step
across the mesh from the constraints above. The versioned double-buffer
flip therefore commits on every shard on the same tick by construction
(there is exactly one ``try_flip`` call site), and
``collective_flip_check`` makes that guarantee *observable*: after a
commit the engine all-reduces the flipped version across every mesh
device (a real pmin/pmax collective, fully-manual ``shard_map``) and
verifies min == max == the registry's version. A future multi-controller
deployment keeps the same check; today it is the mesh-wide barrier the
sharded test tier and ``benchmarks/serving_sharded.py`` assert on.

CPU caveats (jax 0.4.37, ``--xla_force_host_platform_device_count``):
the collective runs fully manual (partial-auto shard_map emits
PartitionId, unsupported by the CPU SPMD partitioner) on int32 operands
(bf16 in-shard_map reductions trip XLA-CPU's AllReducePromotion check).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_mesh
from repro.sharding.rules import (cache_specs, paged_cache_specs,
                                  param_specs, serving_table_specs)


def serving_mesh(mesh_shape=None):
    """The engine's 2-d ("data", "model") mesh. ``mesh_shape=None``
    spreads the batch axis over every visible device: (n_devices, 1)."""
    if mesh_shape is None:
        mesh_shape = (len(jax.devices()), 1)
    return make_mesh(tuple(mesh_shape), ("data", "model"))


def data_size(mesh):
    return mesh.shape["data"]


def _sanitize(shape_tree, spec_tree, mesh):
    """Drop mesh axes from dims they do not divide (the
    ``launch.entry.sanitize_specs`` rule, local so serving does not pull
    in the launch entry builders)."""
    def fix(leaf, spec):
        dims = []
        for d, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                dims.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            dims.append(ax if d % size == 0 else None)
        return P(*dims)

    return jax.tree_util.tree_map(fix, shape_tree, spec_tree)


def place(tree, spec_tree, mesh):
    """device_put every leaf with its NamedSharding (committed layout —
    jit will neither copy nor re-decide these)."""
    return jax.tree_util.tree_map(
        lambda leaf, spec: jax.device_put(leaf, NamedSharding(mesh, spec)),
        tree, spec_tree)


def shard_params(cfg, params, mesh):
    """Base weights placed tensor-parallel (divisibility-sanitized)."""
    specs = _sanitize(params, param_specs(cfg, params, mesh), mesh)
    return place(params, specs, mesh), specs


def shard_tables(registry, mesh):
    """Spec tree for a registry's packed tables (see
    ``serving_table_specs``), sanitized against the mesh."""
    tables = registry.tables
    specs = serving_table_specs(tables, registry.local_tree, mesh)
    return _sanitize(tables, specs, mesh)


def shard_cache(cfg, cache, mesh, *, paged):
    """KV cache placed on the mesh: page axis (paged) or batch axis
    (dense) over "data", heads over "model" when divisible."""
    builder = paged_cache_specs if paged else cache_specs
    specs = _sanitize(cache, builder(cfg, cache, mesh), mesh)
    return place(cache, specs, mesh), specs


def constrain_rows(x, mesh):
    """``with_sharding_constraint`` splitting a leading batch/row axis
    over "data" — identity when the axis does not divide (small prefill
    groups stay replicated rather than unevenly padded)."""
    if x.ndim == 0 or x.shape[0] % data_size(mesh) != 0:
        return x
    spec = P(*(("data",) + (None,) * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _shard_map_all(fn, mesh, in_specs, out_specs):
    """Fully-manual shard_map over EVERY mesh axis (jax version compat;
    fully manual because the CPU SPMD partitioner rejects partial-auto)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh,
                             axis_names=set(mesh.axis_names),
                             in_specs=in_specs, out_specs=out_specs,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


@functools.lru_cache(maxsize=8)
def _flip_check_fn(mesh):
    axes = tuple(mesh.axis_names)

    def agree(v):
        lo, hi = v, v
        for ax in axes:
            lo = jax.lax.pmin(lo, ax)
            hi = jax.lax.pmax(hi, ax)
        return lo, hi

    return jax.jit(_shard_map_all(agree, mesh, in_specs=P(),
                                  out_specs=(P(), P())))


def collective_flip_check(mesh, version):
    """All-reduce ``version`` across every device of the mesh; returns
    (min, max) as python ints. The refresh path calls this after every
    committed flip and asserts min == max == version — the observable
    form of 'all shards flipped the same round on the same tick'."""
    lo, hi = _flip_check_fn(mesh)(jnp.asarray(np.int32(version)))
    return int(lo), int(hi)
