"""Multi-tenant personalized-adapter serving for FedSA-LoRA.

At serving time the paper's structure — one aggregated Ā shared by every
client, a client-specific B_i per tenant — means millions of personalized
models differ only by a tiny rank-r×N matrix. One base forward plus one
shared x·Ā projection can therefore serve a *mixed* batch of clients:

  ``registry``   AdapterRegistry: LRU slot tables packing the hot B_i set
  ``scheduler``  continuous-batching FIFO scheduler over decode rows
  ``engine``     ServingEngine: prefill/decode loop + throughput metrics

The matching compute primitive is ``repro.kernels.bgmv`` (grouped
shared-Ā LoRA matmul); the model-integration path is the grouped branch
of ``repro.models.common.lora_delta``.
"""
from repro.serving.engine import ServingEngine
from repro.serving.registry import AdapterRegistry, gather_adapters
from repro.serving.scheduler import Request, Scheduler, Sequence

__all__ = ["AdapterRegistry", "gather_adapters", "Request", "Scheduler",
           "Sequence", "ServingEngine"]
