"""Multi-tenant personalized-adapter serving for FedSA-LoRA.

At serving time the paper's structure — one aggregated Ā shared by every
client, a client-specific B_i per tenant — means millions of personalized
models differ only by a tiny rank-r×N matrix. One base forward plus one
shared x·Ā projection can therefore serve a *mixed* batch of clients:

  ``registry``   AdapterRegistry: LRU slot tables packing the hot B_i set
  ``scheduler``  continuous-batching FIFO scheduler over decode rows
  ``engine``     ServingEngine: prefill/decode loop + throughput metrics
  ``refresh``    live train→serve bridge: AdapterFeed pub/sub channel +
                 versioned double-buffered slot tables, so a federation
                 round's new Ā/B_i is absorbed mid-stream with no batch
                 drain and token parity for in-flight sequences
  ``sharded``    mesh placement for ``ServingConfig(shard_serving=True)``:
                 params tensor-parallel over "model", KV pages and decode
                 rows over "data", slot tables replicated, and the
                 collective flip check that makes every shard commit a
                 refresh on the same tick

The registry is not FedSA-only: modes whose clients own their whole
adapter pair (FedIT-style plain LoRA, FedDPA personal adapters) pack
per-client A tables next to the B tables, and the generic SGMV gather
serves them — including mode-heterogeneous fleets — in the same grouped
batch (``repro.serving.demo.mixed_fleet`` fabricates such populations).

The matching compute primitives are ``repro.kernels.bgmv`` (grouped
shared-Ā LoRA matmul; engine config ``lora_backend="bgmv"``),
``repro.kernels.sgmv`` (generic grouped matmul, BOTH matrices per row;
``lora_backend="sgmv"``) and ``repro.kernels.paged_attention``
(block-table decode attention; engine config ``attn_backend="pallas"``);
the jnp paths are the grouped branch of
``repro.models.common.lora_delta`` and the gather in
``repro.models.attention.attn_decode_paged``. K/V lives in a paged pool
(``PagePool`` + scheduler-owned block tables) with the PR-1 dense layout
kept as ``kv_layout="dense"`` fallback. ``docs/serving.md`` is the
architecture guide for the whole subsystem.
"""
from repro.serving.config import ServingConfig
from repro.serving.engine import ServingEngine
from repro.serving.prefix import PrefixCache
from repro.serving.refresh import (AdapterFeed, snapshot_clients,
                                   train_and_serve)
from repro.serving.registry import (AdapterRegistry, gather_adapters,
                                    gather_adapters_versioned)
from repro.serving.scheduler import (PagePool, Request, Scheduler, Sequence,
                                     bucket_len, prefill_batches)
from repro.serving.sharded import (collective_flip_check, serving_mesh,
                                   shard_cache, shard_params, shard_tables)
from repro.serving.store import AdapterStore, Prefetcher

__all__ = ["AdapterFeed", "AdapterRegistry", "AdapterStore", "Prefetcher",
           "ServingConfig", "gather_adapters", "gather_adapters_versioned",
           "PagePool", "PrefixCache", "Request", "Scheduler", "Sequence",
           "ServingEngine",
           "bucket_len", "collective_flip_check", "prefill_batches",
           "serving_mesh", "shard_cache", "shard_params", "shard_tables",
           "snapshot_clients", "train_and_serve"]
