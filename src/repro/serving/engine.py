"""ServingEngine: registry + scheduler + model → one decode loop.

``step()`` interleaves prefill and decode the way a continuous-batching
server does:

  1. admit queued requests (registry pins a slot; under the paged layout
     the scheduler also reserves KV pages and fills the row's block
     table),
  2. prefill the admitted prompts — **chunked and batched**: prompts are
     packed into length-bucketed groups (padded to power-of-two lengths
     and group sizes so jit compiles O(log max_seq · log max_batch)
     variants) and their K/V is written straight into pages. The dense
     fallback layout keeps the PR-1 behavior: batch-1 prefill scattered
     into a (B, max_seq) cache,
  3. run ONE grouped decode step for the whole mixed-client batch — the
     per-row B_i is gathered from the registry slot tables inside the
     jitted step. The paged decode attends through the block table,
     truncated to the power-of-two page bucket covering the deepest
     active row, so a batch of short sequences never pays for max_seq,
  4. refresh: drain the adapter feed (live train→serve bridge) and
     attempt the deferred double-buffer flip — between the decode tick
     and retirement, so a publish never touches weights a still-active
     row reads,
  5. retire finished rows, freeing row + registry pin, buffer + pages.

With a ``versioned`` registry the jitted steps also carry per-row buffer
ids; the gather is version-indexed (``gather_adapters_versioned``) so a
mixed batch can span two federation rounds — sequences admitted under
round t decode round-t weights to their last token while later rows
already read round t+1, with no prompt recompute, drain, or rebuild.

Backends (``attn_backend``-style config, jnp fallbacks always available):

  ``kv_layout``     "auto" | "paged" | "dense" — KV cache layout
  ``attn_backend``  "xla" (block-table gather + masked softmax) |
                    "pallas" (repro.kernels.paged_attention)
  ``lora_backend``  "jnp" (gather + einsum grouped lora_delta) |
                    "bgmv" (repro.kernels.bgmv fused grouped matmul;
                    needs the batch-global Ā — per-row A falls back to
                    jnp) |
                    "sgmv" (repro.kernels.sgmv fused generic grouped
                    matmul, BOTH matrices per row — personal-A
                    registries and mixed fleets; batches whose gathered
                    A is batch-global take the bgmv fast path)
  ``decode_backend`` "per-tick" (one jitted decode step, one host sync
                    per generated token) | "fused" (up to
                    ``decode_ticks`` ticks inside ONE jitted
                    ``lax.scan`` — sampling, position advance, per-row
                    budget/EOS masking, and the page commit stay on
                    device; host sync — retire, admit/prefill, feed
                    drain, deferred flips — happens only at scan
                    boundaries, so versioned-gather token parity is
                    preserved: a row's (slot, buf) is loop-invariant
                    between syncs)

The registry decides WHAT is per-tenant (B only under FedSA; A and B
under fedit/feddpa packing — see ``repro.serving.registry``); the
engine's gather and decode loop are mode-agnostic, so one engine serves
a mode-heterogeneous fleet as long as every tenant lives in the same
registry. See ``docs/serving.md`` for the full architecture guide and
the support matrix.
"""
from __future__ import annotations

import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import grouped_lora_backend
from repro.models.transformer import (decode_scan, decode_scan_paged,
                                      decode_step, decode_step_paged,
                                      init_cache, init_paged_cache,
                                      paged_unsupported_reason, prefill,
                                      prefill_paged, prefill_paged_suffix,
                                      segments)
from repro.obs import MetricsRegistry, annotate, named_scope
from repro.serving.config import FIELD_NAMES, ServingConfig
from repro.serving.prefix import PrefixCache
from repro.serving.registry import (gather_adapters,
                                    gather_adapters_versioned)
from repro.serving.scheduler import (PagePool, Scheduler, bucket_len,
                                     prefill_batches)
from repro.serving.sharded import (collective_flip_check, constrain_rows,
                                   data_size, serving_mesh, shard_cache,
                                   shard_params, shard_tables)


def _scatter_row(big, small, row):
    """Insert a batch-1 cache pytree into row ``row`` of the batch cache.
    Every non-hybrid cache leaf carries batch at axis 1: (n, B, ...)."""
    def one(dst, src):
        start = (0, row) + (0,) * (dst.ndim - 2)
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)
    return jax.tree_util.tree_map(one, big, small)


class ServingEngine:
    def __init__(self, cfg, params, acfg, registry, config=None, *,
                 feed=None, metrics=None, trace=None, **legacy):
        """``config`` is a ``ServingConfig`` — THE way to configure an
        engine (cross-field validation already ran in its
        ``__post_init__``). The former 17 loose kwargs (``max_batch``,
        ``kv_layout``, ...) still work for one release: they fold into
        a config (on top of ``config`` when both are given) with a
        ``DeprecationWarning``. ``feed``/``metrics``/``trace`` stay
        real kwargs — they are live objects, not configuration."""
        if legacy:
            unknown = sorted(set(legacy) - FIELD_NAMES)
            if unknown:
                raise TypeError(
                    f"ServingEngine got unexpected keyword arguments "
                    f"{unknown} (known config fields: "
                    f"{sorted(FIELD_NAMES)})")
            warnings.warn(
                "loose ServingEngine kwargs ("
                + ", ".join(sorted(legacy))
                + ") are deprecated; pass config=ServingConfig(...) — "
                "folding them into a config for now (removed next "
                "release)", DeprecationWarning, stacklevel=2)
            config = (config if config is not None
                      else ServingConfig()).replace(**legacy)
        elif config is None:
            config = ServingConfig()
        self.config = config
        max_batch, max_seq = config.max_batch, config.max_seq
        cache_dtype = config.cache_dtype
        kv_layout, page_size = config.kv_layout, config.page_size
        n_pages = config.n_pages
        attn_backend = config.attn_backend
        lora_backend = config.lora_backend
        decode_backend = config.decode_backend
        decode_ticks, eos_id = config.decode_ticks, config.eos_id
        max_queue = config.max_queue
        request_deadline_s = config.request_deadline_s
        degrade_after_s = config.degrade_after_s
        if cfg.family == "hybrid":
            raise NotImplementedError(
                "hybrid cache layout (inner axis before batch) not wired")
        if any(s["kind"] == "dec_attn" for s in segments(cfg)):
            raise NotImplementedError("enc-dec serving needs frame plumbing")
        if cfg.mla is not None:
            raise NotImplementedError(
                "MLA decode merges W+ΔW via effective_weight, which has no "
                "grouped per-row-B form yet")
        paged_reason = paged_unsupported_reason(cfg)
        if kv_layout == "auto":
            kv_layout = "dense" if paged_reason else "paged"
        elif kv_layout == "paged" and paged_reason:
            raise NotImplementedError(paged_reason)
        self.versioned = getattr(registry, "versioned", False)
        if feed is not None and not self.versioned:
            raise ValueError("an adapter feed needs a double-buffered "
                             "registry (AdapterRegistry versioned=True)")
        self.cfg, self.params, self.acfg = cfg, params, acfg
        self.registry = registry
        # adapter tiering (repro.serving.store): apply the config's tier
        # bounds to the registry (entries migrate in place) and remember
        # how many queued admits to prefetch host-ward each tick
        if config.tiered and hasattr(registry, "configure_tiers"):
            registry.configure_tiers(host_ring_slots=config.host_ring_slots,
                                     cold_dir=config.cold_dir)
        self.prefetch_lookahead = config.prefetch_lookahead
        self.feed = feed
        self.max_batch, self.max_seq = max_batch, max_seq
        self.kv_layout = kv_layout
        self.attn_backend, self.lora_backend = attn_backend, lora_backend
        self.decode_backend = decode_backend
        self.decode_ticks = decode_ticks
        self.eos_id = eos_id
        # robustness knobs (docs/robustness.md): bounded admission queue
        # (shed past max_queue), per-request submit→retire deadline
        # (overdue rows retire cleanly with deadline_exceeded), degraded
        # base-model serving when no adapter slot can be acquired
        self.request_deadline_s = request_deadline_s

        # observability (repro.obs): a MetricsRegistry by default
        # (report()'s latency percentiles ride its histograms);
        # metrics=False opts out entirely (the uninstrumented arm of
        # the overhead guard in tests/test_obs.py). trace is opt-in —
        # pass a TraceLog to get the structured event timeline.
        if metrics is None:
            metrics = MetricsRegistry()
        self.metrics = metrics or None
        self.trace = trace
        if self.metrics is not None:
            m = self.metrics
            self._h_queue = m.histogram(
                "repro_serve_queue_wait_seconds", "submit→admit wait")
            self._h_ttft = m.histogram(
                "repro_serve_ttft_seconds", "submit→first-token latency")
            self._h_itl = m.histogram(
                "repro_serve_intertoken_seconds", "inter-token gap")
            self._h_e2e = m.histogram(
                "repro_serve_e2e_seconds", "submit→retire latency")
            self._h_prefill = m.histogram(
                "repro_serve_prefill_batch_seconds",
                "wall per prefill batch")
            self._h_decode = m.histogram(
                "repro_serve_decode_phase_seconds",
                "wall per decode phase (one jitted dispatch)")
            self._c_requests = m.counter(
                "repro_serve_requests_total", "retired requests")
            self._c_decoded = m.counter(
                "repro_serve_tokens_decoded_total", "decode tokens")
            self._c_prefilled = m.counter(
                "repro_serve_tokens_prefilled_total", "prompt tokens")
            self._g_occ = m.gauge(
                "repro_serve_batch_occupancy", "active rows / max_batch")
            self._g_pool = m.gauge(
                "repro_serve_pool_occupancy", "used pages / capacity")
            self._c_shed = m.counter(
                "repro_serve_shed_total", "requests shed unserved")
            self._c_deadline = m.counter(
                "repro_serve_deadline_total",
                "rows retired by the deadline sweep")
            self._c_degraded = m.counter(
                "repro_serve_degraded_total",
                "requests served base-model (degraded)")
            self._c_prefix_hits = m.counter(
                "repro_serve_prefix_hits_total",
                "admissions that reused cached prefix pages")
            self._c_prefix_tokens = m.counter(
                "repro_serve_prefix_tokens_total",
                "prompt tokens skipped via prefix reuse")
            self._c_pages_shared = m.counter(
                "repro_serve_pages_shared_total",
                "physical pages attached by refcount instead of alloc")
            self._c_cow = m.counter(
                "repro_serve_cow_copies_total",
                "copy-on-write page copies before a shared-page write")
            self._c_prefix_evict = m.counter(
                "repro_serve_prefix_evict_total",
                "cached prefix entries evicted under pool pressure")
        # registry-side events/latency report through the same sinks
        if registry.trace is None:
            registry.trace = trace
        if registry.metrics is None:
            registry.metrics = self.metrics
        self.tick = 0                   # step() count (trace tick ids)
        self._shed_seen = 0             # scheduler.shed mirrored to obs
        # scheduler/prefix lifetime counters mirrored into obs counters
        # by delta (same pattern as _sync_shed_counter)
        self._prefix_seen = {"prefix_hits": 0, "prefix_hit_tokens": 0,
                             "pages_shared": 0, "evictions": 0}

        # mesh-sharded serving (repro.serving.sharded): base weights
        # tensor-parallel over "model", page pool / decode rows over
        # "data", adapter tables replicated over "data" (col-parallel B
        # over "model"). The engine stays single-controller — GSPMD
        # partitions the jitted steps from the placements + row
        # constraints below.
        self.mesh = None
        self.collective_flips = 0
        self._flips_seen = getattr(registry, "flips", 0)
        n_row_shards = 1
        if config.shard_serving:
            shape = config.mesh_shape or (len(jax.devices()), 1)
            n_row_shards = shape[0]
            # validated BEFORE mesh construction so invalid combos are
            # rejected even on hosts exposing a single device
            if max_batch % n_row_shards != 0:
                raise ValueError(
                    f"mesh data axis {n_row_shards} must divide "
                    f"max_batch={max_batch}")
            if registry.n_slots % n_row_shards != 0:
                raise ValueError(
                    f"mesh data axis {n_row_shards} must divide the "
                    f"registry's n_slots={registry.n_slots} — adapter "
                    "capacity splits evenly across row shards")
            self.mesh = serving_mesh(config.mesh_shape)
            self.params = params = shard_params(cfg, params, self.mesh)[0]
            registry.place(self.mesh, shard_tables(registry, self.mesh))
        if config.prefix_cache and kv_layout != "paged":
            # config rejects explicit dense; this catches auto-resolved
            # dense (model families the paged layout cannot serve)
            raise ValueError(
                f"prefix_cache needs the paged KV layout, but this model "
                f"resolved kv_layout='dense' ({paged_reason})")
        if kv_layout == "paged":
            self.page_size = page_size
            # table width covers the largest prefill bucket (pow2 >= max_seq)
            self.table_pages = bucket_len(max_seq, page_size) // page_size
            if n_pages is None:        # worst case + the write-off page
                n_pages = max_batch * (-(-max_seq // page_size)) + 1
            # a sharded pool rounds up so the page axis block-partitions
            # evenly over "data" (paged_cache_specs falls back to
            # replicated otherwise) and each row shard owns a whole
            # contiguous block of pages
            n_pages = -(-n_pages // n_row_shards) * n_row_shards
            self.pool = PagePool(n_pages, page_size, n_shards=n_row_shards)
            self.prefix = (PrefixCache(self.pool,
                                       chunk_pages=config.prefix_chunk_pages,
                                       trace=trace)
                           if config.prefix_cache else None)
            self.scheduler = Scheduler(max_batch, pool=self.pool,
                                       table_pages=self.table_pages,
                                       trace=trace, max_queue=max_queue,
                                       degrade_after_s=degrade_after_s,
                                       prefix=self.prefix)
            self.cache = init_paged_cache(cfg, n_pages, page_size,
                                          cache_dtype)
        else:
            self.pool = None
            self.prefix = None
            self.scheduler = Scheduler(max_batch, trace=trace,
                                       max_queue=max_queue,
                                       degrade_after_s=degrade_after_s)
            self.cache = init_cache(cfg, max_batch, max_seq, cache_dtype)
        if self.mesh is not None:
            self.cache = shard_cache(cfg, self.cache, self.mesh,
                                     paged=kv_layout == "paged")[0]
        self._toks = np.zeros((max_batch, 1), np.int32)
        self._pos = np.zeros((max_batch,), np.int32)
        self._slots = np.zeros((max_batch,), np.int32)
        self._bufs = np.zeros((max_batch,), np.int32)
        self.finished = {}              # rid → dict(client_id, tokens, ...)
        self.prefill_retraces = 0       # jit trace counts (never reset)
        self.decode_retraces = 0
        self.reset_stats()
        local = registry.local_tree
        # registries with a degraded zero slot stride their tables by
        # n_slots + 1; older/minimal registries fall back to n_slots
        slot_stride = getattr(registry, "slot_stride", registry.n_slots)
        engine = self

        def _adapters(tree):
            # registry templates are either the adapters tree itself or a
            # full trainables tree ({"adapters": ..., "cls_head": ...})
            return tree["adapters"] if "adapters" in tree else tree

        if self.versioned:
            def _gather(tables, slots, bufs):
                return _adapters(gather_adapters_versioned(
                    tables, local, slots, bufs, slot_stride))
        else:
            # bufs rides the signature unused — XLA drops it, and both
            # registry kinds share one set of step functions
            def _gather(tables, slots, bufs):
                return _adapters(gather_adapters(tables, local, slots))

        # sharded engines pin every per-row operand (tokens, positions,
        # slot/buf ids, block tables) and the per-row outputs to
        # P("data", ...) inside the jitted steps, so GSPMD splits the
        # batch instead of replicating it; identity on plain engines and
        # on axes the mesh does not divide (small prefill groups)
        if self.mesh is not None:
            mesh = self.mesh

            def _rows(*xs):
                out = tuple(constrain_rows(x, mesh) for x in xs)
                return out if len(out) > 1 else out[0]
        else:
            def _rows(*xs):
                return xs if len(xs) > 1 else xs[0]

        # jax.named_scope names the HLO under each serving phase so a
        # jax.profiler device capture attributes kernels back to the
        # phase (and lines up with the host-side TraceLog timeline)
        def _prefill_dense_fn(tables, slot, buf, tokens):
            engine.prefill_retraces += 1
            with named_scope("serve.prefill_dense"):
                ad = _gather(tables, slot[None], buf[None])
                logits, cache1, _ = prefill(cfg, params, ad, acfg, tokens,
                                            max_seq, cache_dtype=cache_dtype)
                return (jnp.argmax(logits[:, -1], -1).astype(jnp.int32),
                        cache1)

        def _prefill_paged_fn(tables, slots, bufs, tokens, lengths, bts,
                              cache):
            engine.prefill_retraces += 1
            with named_scope("serve.prefill_paged"):
                slots, bufs, tokens, lengths, bts = _rows(
                    slots, bufs, tokens, lengths, bts)
                ad = _gather(tables, slots, bufs)
                with grouped_lora_backend(engine.lora_backend):
                    logits, cache = prefill_paged(cfg, params, ad, acfg,
                                                  tokens, lengths, cache,
                                                  bts)
                return _rows(jnp.argmax(logits, -1).astype(jnp.int32)), cache

        def _prefill_suffix_fn(tables, slots, bufs, tokens, lengths,
                               prefix_lens, bts, dst, cache):
            # suffix-only prefill for prefix-cache hits: the rows' prefix
            # KV is already resident in shared pages reachable through
            # bts; only the divergent suffix runs the model. Never
            # sharded — prefix_cache + shard_serving is rejected at
            # config time, so no _rows constraints here.
            engine.prefill_retraces += 1
            with named_scope("serve.prefill_suffix"):
                ad = _gather(tables, slots, bufs)
                with grouped_lora_backend(engine.lora_backend):
                    logits, cache = prefill_paged_suffix(
                        cfg, params, ad, acfg, tokens, lengths,
                        prefix_lens, cache, bts, dst)
                return jnp.argmax(logits, -1).astype(jnp.int32), cache

        def _copy_page_fn(cache, src, dst):
            # copy-on-write: duplicate physical page src into dst across
            # every layer pool of every segment (one fused dispatch)
            with named_scope("serve.cow_copy"):
                return [{"k": e["k"].at[:, dst].set(e["k"][:, src]),
                         "v": e["v"].at[:, dst].set(e["v"][:, src])}
                        for e in cache]

        def _decode_dense_fn(tables, slots, bufs, toks, pos, cache):
            engine.decode_retraces += 1
            with named_scope("serve.decode_dense"):
                slots, bufs, toks, pos = _rows(slots, bufs, toks, pos)
                ad = _gather(tables, slots, bufs)
                with grouped_lora_backend(engine.lora_backend):
                    logits, cache = decode_step(cfg, params, ad, acfg, toks,
                                                pos, cache)
                return (_rows(jnp.argmax(logits[:, 0], -1)
                              .astype(jnp.int32)), cache)

        def _decode_paged_fn(tables, slots, bufs, toks, pos, bts, cache):
            engine.decode_retraces += 1
            with named_scope("serve.decode_paged"):
                slots, bufs, toks, pos, bts = _rows(slots, bufs, toks,
                                                    pos, bts)
                ad = _gather(tables, slots, bufs)
                with grouped_lora_backend(engine.lora_backend):
                    logits, cache = decode_step_paged(
                        cfg, params, ad, acfg, toks, pos, cache, bts,
                        attn_backend=engine.attn_backend)
                return (_rows(jnp.argmax(logits[:, 0], -1)
                              .astype(jnp.int32)), cache)

        # fused multi-tick scans: the adapter gather hoists OUT of the
        # tick loop (slot/buf ids are loop-invariant between host syncs,
        # so bgmv/sgmv see exactly the per-tick operands), n_ticks is a
        # static arg (one compiled variant per pow2 tick count)
        def _decode_scan_dense_fn(tables, slots, bufs, toks, pos, budget,
                                  cache, n_ticks):
            engine.decode_retraces += 1
            with named_scope("serve.decode_scan_dense"):
                slots, bufs, toks, pos, budget = _rows(slots, bufs, toks,
                                                       pos, budget)
                ad = _gather(tables, slots, bufs)
                with grouped_lora_backend(engine.lora_backend):
                    return decode_scan(cfg, params, ad, acfg, toks, pos,
                                       budget, cache, n_ticks=n_ticks,
                                       eos_id=engine.eos_id)

        def _decode_scan_paged_fn(tables, slots, bufs, toks, pos, budget,
                                  bts, cache, n_ticks):
            engine.decode_retraces += 1
            with named_scope("serve.decode_scan_paged"):
                slots, bufs, toks, pos, budget, bts = _rows(
                    slots, bufs, toks, pos, budget, bts)
                ad = _gather(tables, slots, bufs)
                with grouped_lora_backend(engine.lora_backend):
                    return decode_scan_paged(
                        cfg, params, ad, acfg, toks, pos, budget, cache,
                        bts, n_ticks=n_ticks, eos_id=engine.eos_id,
                        attn_backend=engine.attn_backend)

        # paged prefill retraces per (group, bucket) pair; decode per page
        # bucket — both O(log) families. The dense fallback retraces per
        # distinct prompt length and compiles decode once.
        # donate the cache on every path so updates can reuse the buffers
        # in place instead of copying the whole cache each step (the paged
        # step is structured so its one post-scan scatter per pool actually
        # aliases; the dense scan-carried cache benefits where XLA can)
        if kv_layout == "paged":
            self._prefill = jax.jit(_prefill_paged_fn, donate_argnums=(6,))
            self._prefill_suffix = jax.jit(_prefill_suffix_fn,
                                           donate_argnums=(8,))
            self._copy_page = jax.jit(_copy_page_fn, donate_argnums=(0,))
            self._decode = jax.jit(_decode_paged_fn, donate_argnums=(6,))
            self._decode_scan = jax.jit(_decode_scan_paged_fn,
                                        static_argnums=(8,),
                                        donate_argnums=(7,))
        else:
            self._prefill = jax.jit(_prefill_dense_fn)
            self._decode = jax.jit(_decode_dense_fn, donate_argnums=(5,))
            self._decode_scan = jax.jit(_decode_scan_dense_fn,
                                        static_argnums=(7,),
                                        donate_argnums=(6,))
            self._scatter = jax.jit(_scatter_row, donate_argnums=(0,))

    def reset_stats(self):
        """Zero throughput counters (e.g. after a warm-up pass); keeps the
        compiled functions, cache buffers, and registry residency.
        Obs histograms/gauges reset with the window; obs counters stay
        lifetime-monotonic (Prometheus semantics)."""
        if self.metrics is not None:
            self.metrics.reset_window()
        self.finished = {}
        self.deadline_retired = 0
        self.degraded_served = 0
        self.cow_copies = 0
        self.decoded_tokens = self.prefill_tokens = self.decode_steps = 0
        self.prefilled_requests = self.prefill_batch_count = 0
        self.host_syncs = 0             # steps that ran a decode phase
        self.fused_scans = self.fused_ticks = 0
        self.fused_tick_shrinks = 0
        self._pages_window_reserved = self._pages_window_used = 0
        self._occ_sum = 0.0
        self._page_util_sum = 0.0
        self._pool_occ_sum = 0.0
        self._decode_wall = 0.0
        self._stale_sum = 0
        self._stale_rows = 0
        self._stale_max = 0
        self._tenant_stale = {}         # client_id → max observed staleness
        self._t0 = None
        self.registry.hits = self.registry.misses = 0
        self.registry.evictions = 0
        s = self.scheduler
        s.prefix_lookups = s.prefix_hits = 0
        s.prefix_hit_tokens = s.pages_shared = 0
        for k in self._prefix_seen:
            self._prefix_seen[k] = 0
        if hasattr(self.registry, "reset_tier_stats"):
            self.registry.reset_tier_stats()

    # -- request plane ------------------------------------------------------
    def submit(self, client_id, prompt, max_new_tokens=16, deadline_s=None):
        """Queue one request. Returns its rid — or None when the bounded
        admission queue shed it (backpressure; the caller may retry
        later). ``deadline_s`` overrides the engine-wide
        ``request_deadline_s`` submit→retire budget for this request."""
        assert len(prompt) + max_new_tokens <= self.max_seq, \
            "prompt + generation exceeds engine max_seq"
        if self.pool is not None:
            assert (self.pool.pages_needed(len(prompt) + max_new_tokens)
                    <= self.pool.capacity), \
                "request needs more KV pages than the pool holds"
        if deadline_s is None:
            deadline_s = self.request_deadline_s
        rid = self.scheduler.submit(client_id, prompt, max_new_tokens,
                                    deadline_s=deadline_s)
        self._sync_shed_counter()
        return rid

    def _sync_shed_counter(self):
        """Mirror the scheduler's lifetime shed count into the obs
        counter (sheds happen both at submit and inside admit's overdue
        sweep, so the engine diffs rather than double-booking)."""
        if self.metrics is not None:
            d = self.scheduler.shed - self._shed_seen
            if d > 0:
                self._c_shed.inc(d)
        self._shed_seen = self.scheduler.shed

    def _sync_prefix_counters(self):
        """Mirror the scheduler's/cache's lifetime prefix counters into
        the obs counters by delta (hits/shares land inside admit, evicts
        inside evict_for — neither holds the metrics handles)."""
        if self.prefix is None:
            return
        s = self.scheduler
        pairs = (("prefix_hits", s.prefix_hits, "_c_prefix_hits"),
                 ("prefix_hit_tokens", s.prefix_hit_tokens,
                  "_c_prefix_tokens"),
                 ("pages_shared", s.pages_shared, "_c_pages_shared"),
                 ("evictions", self.prefix.evictions, "_c_prefix_evict"))
        for key, value, counter in pairs:
            d = value - self._prefix_seen[key]
            if d > 0 and self.metrics is not None:
                getattr(self, counter).inc(d)
            self._prefix_seen[key] = value

    # -- serving loop -------------------------------------------------------
    def step(self):
        """One scheduler tick: refresh adapters, admit/prefill new
        requests, decode — ONE token per active row under the per-tick
        backend, up to ``decode_ticks`` tokens in one fused on-device
        scan under the fused backend — refresh again (flips unblock
        between the decode phase and retirement), retire finished
        sequences. Either way this is exactly one host sync: all
        scheduler/registry bookkeeping lives at step boundaries."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        self.tick += 1
        if self.trace is not None:
            self.trace.current_tick = self.tick
        # publishes that unblocked at the last tick's retirement commit
        # here, so this tick's admissions already read the new round
        self._refresh()
        admitted = self.scheduler.admit(self.registry)
        self._sync_shed_counter()      # admit's overdue sweep may shed
        self._sync_prefix_counters()   # hits/shares/evictions in admit
        # the queue heads left behind are the NEXT admits: issue their
        # host-ward prefetches now, so the promotion I/O overlaps the
        # prefill + decode device work below instead of stalling a
        # future admission on a cold npz load
        self._issue_prefetches()
        if self.kv_layout == "paged":
            self._prefill_paged_groups(admitted)
        else:
            self._prefill_dense_rows(admitted)
        if admitted:
            # drain the async prefill→cache chain so its cost is charged
            # to prefill, not to the decode step that would block on it
            jax.block_until_ready(self.cache)
        self._retire_done()
        if self.scheduler.active:
            self.host_syncs += 1
            rows = len(self.scheduler.active)
            t0 = time.perf_counter()
            if self.decode_backend == "fused":
                ticks = self._decode_fused_phase()
            else:
                self._decode_per_tick_phase()
                ticks = 1
            wall = time.perf_counter() - t0
            self._decode_wall += wall
            if self.metrics is not None:
                self._h_decode.observe(wall)
            if self.trace is not None:
                self.trace.emit("decode_scan", ticks=ticks, rows=rows,
                                wall_s=wall)
            self._refresh()
            self._retire_done()

    def _account_token(self, seq, tok):
        """Book one decoded token on its sequence + staleness stats.
        Returns True when the token ends the sequence early (eos)."""
        seq.generated.append(tok)
        seq.pos += 1
        self.decoded_tokens += 1
        stale = self.registry.version - seq.version
        self._stale_sum += stale
        self._stale_rows += 1
        self._stale_max = max(self._stale_max, stale)
        cid = seq.request.client_id
        self._tenant_stale[cid] = max(self._tenant_stale.get(cid, 0), stale)
        if self.eos_id is not None and tok == self.eos_id:
            seq.finished = True
            return True
        return False

    def _tick_pool_stats(self, ticks=1):
        self._occ_sum += self.scheduler.occupancy * ticks
        if self.metrics is not None:
            self._g_occ.set(self.scheduler.occupancy)
        if self.pool is not None:
            used = self.pool.used_count
            held = sum(s.pos + 1 for s in self.scheduler.active.values())
            self._page_util_sum += (held / (used * self.page_size)
                                    if used else 0.0) * ticks
            self._pool_occ_sum += used / self.pool.capacity * ticks
            if self.metrics is not None:
                self._g_pool.set(used / self.pool.capacity)

    def _decode_per_tick_phase(self):
        """One grouped decode step + host bookkeeping for every row."""
        if self.kv_layout == "paged":
            with annotate("serve.decode"):
                out = self._decode_paged_step()
        else:
            with annotate("serve.decode"):
                out, self.cache = self._decode(
                    self.registry.tables, jnp.asarray(self._slots),
                    jnp.asarray(self._bufs), jnp.asarray(self._toks),
                    jnp.asarray(self._pos), self.cache)
                out = np.asarray(out)
        now = time.perf_counter()
        for row, seq in list(self.scheduler.active.items()):
            tok = int(out[row])
            self._account_token(seq, tok)
            if self.metrics is not None:
                self._h_itl.observe(now - seq.t_last)
            seq.t_last = now
            self._toks[row, 0] = tok
            self._pos[row] = seq.pos
        if self.metrics is not None:
            self._c_decoded.inc(len(self.scheduler.active))
        self.decode_steps += 1
        self._tick_pool_stats()

    def _decode_fused_phase(self):
        """Fused phase: one jitted ``decode_scan[_paged]`` runs T ticks
        on device; the host walks the (T, B) token block afterwards,
        mirroring the device's budget/EOS masking exactly (a finished
        row's later pad emissions are never booked)."""
        active = self.scheduler.active
        budgets = np.zeros((self.max_batch,), np.int32)
        for row, seq in active.items():
            budgets[row] = seq.budget
        T = self._plan_ticks(budgets)
        self.fused_scans += 1
        self.fused_ticks += T
        if self.pool is not None:
            self._pages_window_reserved += sum(
                self.pool.pages_needed(s.pos + min(T, s.budget))
                - self.pool.pages_needed(s.pos) for s in active.values())
        if self.kv_layout == "paged":
            self._cow_pass(T)
        pos_before = {row: s.pos for row, s in active.items()}
        with annotate("serve.decode_scan"):
            if self.kv_layout == "paged":
                # bucket the table to the deepest position any row can
                # REACH inside the window (per-tick buckets max_pos + 1)
                max_need = max(s.pos + min(T, s.budget)
                               for s in active.values())
                npg = self._bucketed_npages(max_need)
                bts = jnp.asarray(self.scheduler.block_tables[:, :npg])
                out, _, _, _, self.cache = self._decode_scan(
                    self.registry.tables, jnp.asarray(self._slots),
                    jnp.asarray(self._bufs), jnp.asarray(self._toks),
                    jnp.asarray(self._pos), jnp.asarray(budgets), bts,
                    self.cache, T)
            else:
                out, _, _, _, self.cache = self._decode_scan(
                    self.registry.tables, jnp.asarray(self._slots),
                    jnp.asarray(self._bufs), jnp.asarray(self._toks),
                    jnp.asarray(self._pos), jnp.asarray(budgets),
                    self.cache, T)
            out = np.asarray(out)                    # (T, B)
        now = time.perf_counter()
        booked_total = 0
        for row, seq in list(active.items()):
            remaining = int(budgets[row])
            booked = 0
            for t in range(T):
                if remaining <= 0:
                    break
                remaining -= 1
                booked += 1
                if self._account_token(seq, int(out[t, row])):
                    remaining = 0                    # eos: budget zeroed
            if booked and self.metrics is not None:
                # a T-token block arrives at one host sync: book the
                # mean gap once per token of the block
                self._h_itl.observe((now - seq.t_last) / booked, n=booked)
            seq.t_last = now
            booked_total += booked
            self._toks[row, 0] = seq.generated[-1]
            self._pos[row] = seq.pos
            if self.pool is not None:
                self._pages_window_used += (
                    self.pool.pages_needed(seq.pos)
                    - self.pool.pages_needed(pos_before[row]))
        if self.metrics is not None:
            self._c_decoded.inc(booked_total)
        self.decode_steps += T
        self._tick_pool_stats(ticks=T)
        return T

    def _plan_ticks(self, budgets):
        """Ticks for this fused scan: the configured ``decode_ticks``,
        clamped to the deepest remaining per-row budget (an all-finished
        tail tick would be pure waste), floored to a power of two so the
        scan compiles O(log decode_ticks) variants, then shrunk while
        any row's page reservation cannot cover its tick window (spill —
        cannot trigger under the pool's reserve-on-admit policy, which
        pre-reserves the whole sequence; kept as the guard the fused
        phase's write safety actually rests on)."""
        T = min(self.decode_ticks, int(budgets.max()))
        T = max(1, 1 << (T.bit_length() - 1))        # pow2 floor
        if self.pool is not None:
            while T > 1 and not self._window_covered(T):
                if self.trace is not None:
                    self.trace.emit("tick_shrink", from_ticks=T,
                                    to_ticks=T >> 1)
                T >>= 1
                self.fused_tick_shrinks += 1
        return T

    def _window_covered(self, T):
        """Every active row's page reservation covers the positions its
        min(T, budget)-token window can write."""
        return all(
            self.pool.pages_needed(s.pos + min(T, s.budget)) <= len(s.pages)
            for s in self.scheduler.active.values())

    def _issue_prefetches(self):
        """Admission-lookahead prefetch: walk the first
        ``prefetch_lookahead`` distinct clients of the bounded queue and
        queue background host-ward promotions for the cold ones (the
        registry dedups and skips resident/host tenants). Runs at a
        host-sync boundary — the only cost on this thread is a queue
        push per cold client."""
        k = self.prefetch_lookahead
        if not k or not self.scheduler.queue:
            return
        seen = set()
        for req in self.scheduler.queue:
            cid = req.client_id
            if cid in seen:
                continue
            seen.add(cid)
            self.registry.prefetch(cid)
            if len(seen) >= k:
                break

    def _refresh(self):
        """Refresh phase of the live train→serve bridge: drain the
        adapter feed into the registry and attempt the (possibly
        deferred) double-buffer flip. A no-op without a feed and without
        a staged publish, so plain engines pay nothing."""
        if self.feed is not None:
            pub = self.feed.poll()
            if pub is not None:
                version, trees = pub
                self.registry.publish(version, trees)
        if self.versioned:
            self.registry.try_flip()
            # publish→flip is a collective on a mesh: the registry's
            # single flip commit site (publish() flips inline when
            # unblocked, try_flip() otherwise) already lands on every
            # shard on the same tick, and this all-reduce (pmin/pmax of
            # the version across EVERY mesh device) makes that
            # observable — a torn flip would surface as lo != hi.
            # Detected by counter delta so flips committed through
            # either path (or directly on the registry) are verified.
            if (self.mesh is not None
                    and self.registry.flips > self._flips_seen):
                self._flips_seen = self.registry.flips
                version = self.registry.version
                lo, hi = collective_flip_check(self.mesh, version)
                if not lo == hi == version:
                    raise RuntimeError(
                        f"torn collective flip: version {version} but "
                        f"mesh devices report [{lo}, {hi}]")
                self.collective_flips += 1
                if self.trace is not None:
                    self.trace.emit("collective_flip", version=version,
                                    devices=self.mesh.size)

    # -- prefill paths ------------------------------------------------------
    def _prefill_dense_rows(self, admitted):
        """PR-1 fallback: batch-1 prefill per request, row scatter."""
        for seq in admitted:
            row, req = seq.row, seq.request
            t0 = time.perf_counter()
            with annotate("serve.prefill"):
                tok0, cache1 = self._prefill(
                    self.registry.tables, jnp.int32(seq.slot),
                    jnp.int32(seq.buf), jnp.asarray(req.prompt[None]))
                self.cache = self._scatter(self.cache, cache1, row)
            wall = time.perf_counter() - t0
            self._account_prefill(seq, int(tok0[0]))
            self.prefill_batch_count += 1
            if self.metrics is not None:
                self._h_prefill.observe(wall)
            if self.trace is not None:
                self.trace.emit("prefill_batch", bucket=len(req.prompt),
                                rows=1, wall_s=wall)

    def _prefill_paged_groups(self, admitted):
        """Chunked batched prefill: one forward per length bucket, K/V
        written straight into pages through the block table. Prefix-cache
        hits split off into suffix-only groups (the cached prefix KV is
        already resident — only the divergent tail runs the model); after
        prefill every admitted prompt's pages register in the cache so
        later admissions can share them."""
        misses = [s for s in admitted if s.prefix_len == 0]
        hits = [s for s in admitted if s.prefix_len > 0]
        self._prefill_paged_full(misses)
        self._prefill_paged_suffix(hits)
        if self.prefix is not None:
            for seq in admitted:
                if seq.prefix_ns is None:      # cache-bypass fallback row
                    continue
                n = len(seq.request.prompt)
                self.prefix.insert(seq.prefix_ns, seq.request.prompt,
                                   seq.pages[:self.pool.pages_needed(n)])
            self._sync_prefix_counters()

    def _prefill_paged_full(self, admitted):
        for L, group in prefill_batches(admitted, min_len=self.page_size):
            Gp = bucket_len(len(group))          # pad batch to pow2 too
            toks = np.zeros((Gp, L), np.int32)
            lens = np.ones((Gp,), np.int32)      # padding rows read idx 0
            slots = np.zeros((Gp,), np.int32)
            bufs = np.zeros((Gp,), np.int32)
            bts = np.zeros((Gp, self.table_pages), np.int32)
            for g, seq in enumerate(group):
                p = seq.request.prompt
                toks[g, :len(p)] = p
                lens[g] = len(p)
                slots[g] = seq.slot
                bufs[g] = seq.buf
                bts[g] = self.scheduler.block_tables[seq.row]
            t0 = time.perf_counter()
            with annotate("serve.prefill"):
                tok0, self.cache = self._prefill(
                    self.registry.tables, jnp.asarray(slots),
                    jnp.asarray(bufs), jnp.asarray(toks), jnp.asarray(lens),
                    jnp.asarray(bts), self.cache)
                tok0 = np.asarray(tok0)
            wall = time.perf_counter() - t0
            self.prefill_batch_count += 1
            if self.metrics is not None:
                self._h_prefill.observe(wall)
            if self.trace is not None:
                self.trace.emit("prefill_batch", bucket=L, rows=len(group),
                                wall_s=wall)
            for g, seq in enumerate(group):
                self._account_prefill(seq, int(tok0[g]))

    def _prefill_paged_suffix(self, hits):
        """Suffix-only prefill for prefix-cache hits, bucketed by suffix
        length. A full-prompt hit re-runs only its LAST prompt token (the
        logits for the first generated token need its hidden state; the
        recomputed K/V lands on the write-off page — the cached copy
        stays authoritative). Partial hits write suffix K/V into their
        private pages via dst; the shared prefix pages are read-only."""
        groups = {}
        for seq in hits:
            n = len(seq.request.prompt)
            l = n - seq.prefix_len if seq.prefix_len < n else 1
            groups.setdefault(bucket_len(l, self.page_size),
                              []).append(seq)
        for L, group in sorted(groups.items()):
            Gp = bucket_len(len(group))
            toks = np.zeros((Gp, L), np.int32)
            lens = np.ones((Gp,), np.int32)
            plens = np.zeros((Gp,), np.int32)
            slots = np.zeros((Gp,), np.int32)
            bufs = np.zeros((Gp,), np.int32)
            dst = np.zeros((Gp, L // self.page_size), np.int32)
            max_need = L
            for g, seq in enumerate(group):
                p = seq.request.prompt
                n = len(p)
                start = n - 1 if seq.prefix_len >= n else seq.prefix_len
                suf = p[start:]
                toks[g, :len(suf)] = suf
                lens[g] = len(suf)
                plens[g] = start
                slots[g] = seq.slot
                bufs[g] = seq.buf
                if seq.prefix_len < n:
                    # partial hit: suffix starts on a page boundary; its
                    # pages (beyond the shared prefix) take the K/V
                    pi0 = start // self.page_size
                    own = seq.pages[pi0:self.pool.pages_needed(n)]
                    dst[g, :len(own)] = own
                max_need = max(max_need, start + L)
            npg = self._bucketed_npages(max_need)
            bts = np.zeros((Gp, npg), np.int32)
            for g, seq in enumerate(group):
                bts[g] = self.scheduler.block_tables[seq.row][:npg]
            t0 = time.perf_counter()
            with annotate("serve.prefill_suffix"):
                tok0, self.cache = self._prefill_suffix(
                    self.registry.tables, jnp.asarray(slots),
                    jnp.asarray(bufs), jnp.asarray(toks),
                    jnp.asarray(lens), jnp.asarray(plens),
                    jnp.asarray(bts), jnp.asarray(dst), self.cache)
                tok0 = np.asarray(tok0)
            wall = time.perf_counter() - t0
            self.prefill_batch_count += 1
            if self.metrics is not None:
                self._h_prefill.observe(wall)
            if self.trace is not None:
                self.trace.emit("prefill_batch", bucket=L,
                                rows=len(group), wall_s=wall)
            for g, seq in enumerate(group):
                self._account_prefill(seq, int(tok0[g]))

    def _account_prefill(self, seq, first_token):
        seq.generated.append(first_token)
        seq.t_first = seq.t_last = time.perf_counter()
        if self.eos_id is not None and first_token == self.eos_id:
            seq.finished = True          # eos straight out of prefill
        self.prefill_tokens += len(seq.request.prompt)
        self.prefilled_requests += 1
        if self.metrics is not None:
            self._c_prefilled.inc(len(seq.request.prompt))
        self._toks[seq.row, 0] = first_token
        self._pos[seq.row] = seq.pos
        self._slots[seq.row] = seq.slot
        self._bufs[seq.row] = seq.buf

    # -- decode path --------------------------------------------------------
    @staticmethod
    def _page_bucket(n):
        """Smallest {2^k, 3·2^k} ladder value >= n: half-pow2 steps keep
        the attended KV length within 1.5× of the deepest active row at
        ~2·log2 compiled decode variants."""
        b = 1
        while True:
            if n <= b:
                return b
            if n <= 3 * b // 2 and b > 1:
                return 3 * b // 2
            b *= 2

    def _bucketed_npages(self, n_tokens):
        """Block-table width for a batch whose deepest row attends
        ``n_tokens`` positions: the ladder bucket, capped at the pages
        max_seq actually needs (the bucket of a non-pow2 max_seq would
        overshoot the dense layout). One definition — the per-tick and
        fused trace keys must bucket identically."""
        return min(-(-self.max_seq // self.page_size),
                   self._page_bucket(self.pool.pages_needed(n_tokens)))

    def _cow_pass(self, T):
        """Copy-on-write sweep: before a decode window writes positions
        [pos, pos + min(T, budget)) for each active row, any touched page
        whose refcount exceeds 1 (shared with the prefix cache or a
        sibling row) is copied into a private page and the row's block
        table repointed — the decode kernels then never mutate a shared
        page. The page an admission can ever need to CoW is its partial
        tail page, pre-reserved in ``cow_stash`` at admit; the alloc
        fallback covers stash-less rows defensively."""
        if self.prefix is None:
            return
        for seq in self.scheduler.active.values():
            if seq.done or seq.budget <= 0:
                continue
            lo = seq.pos // self.page_size
            hi = (seq.pos + min(T, seq.budget) - 1) // self.page_size
            for pi in range(lo, min(hi, len(seq.pages) - 1) + 1):
                phys = seq.pages[pi]
                if phys == 0 or self.pool.refcount(phys) <= 1:
                    continue
                if seq.cow_stash:
                    dst = seq.cow_stash.pop()
                else:
                    got = self.pool.alloc(1)
                    if got is None:
                        self.prefix.evict_for(self.pool, 1)
                        got = self.pool.alloc(1)
                    if got is None:
                        raise RuntimeError(
                            "copy-on-write found no free page — the "
                            "admission stash invariant was violated")
                    dst = got[0]
                self.cache = self._copy_page(self.cache, jnp.int32(phys),
                                             jnp.int32(dst))
                self.pool.release([phys])        # drop this row's share
                seq.pages[pi] = dst
                self.scheduler.block_tables[seq.row, pi] = dst
                self.cow_copies += 1
                if self.metrics is not None:
                    self._c_cow.inc()
                if self.trace is not None:
                    self.trace.emit("cow_copy", row=seq.row, page=phys)

    def _decode_paged_step(self):
        """Grouped decode through the block table, truncated to the page
        bucket covering the deepest active row (so short batches attend
        over a fraction of max_seq; bounded retraces)."""
        self._cow_pass(1)
        max_pos = max(s.pos for s in self.scheduler.active.values())
        npg = self._bucketed_npages(max_pos + 1)
        bts = jnp.asarray(self.scheduler.block_tables[:, :npg])
        out, self.cache = self._decode(
            self.registry.tables, jnp.asarray(self._slots),
            jnp.asarray(self._bufs), jnp.asarray(self._toks),
            jnp.asarray(self._pos), bts, self.cache)
        return np.asarray(out)

    def _sweep_deadlines(self):
        """Mark active rows whose submit→retire deadline has passed as
        finished: they retire cleanly through ``_retire_done`` with
        whatever tokens they produced, freeing row/pin/pages for the
        queue instead of starving it."""
        now = time.perf_counter()
        for seq in self.scheduler.active.values():
            if seq.done:
                continue
            dl = seq.request.deadline_s
            if dl is not None and now - seq.request.t_submit > dl:
                seq.finished = True
                seq.deadline_hit = True
                self.deadline_retired += 1
                if self.metrics is not None:
                    self._c_deadline.inc()
                if self.trace is not None:
                    self.trace.emit("deadline_exceeded",
                                    rid=seq.request.rid,
                                    client=seq.request.client_id,
                                    tokens=len(seq.generated))

    def _retire_done(self):
        self._sweep_deadlines()
        for row, seq in list(self.scheduler.active.items()):
            if seq.done:
                self.scheduler.retire(row, self.registry)
                if self.pool is not None:
                    # idle rows write to the write-off page at offset 0
                    self._pos[row] = 0
                    self._toks[row, 0] = 0
                req = seq.request
                now = time.perf_counter()
                queue_wait = seq.t_admit - req.t_submit
                ttft = seq.t_first - req.t_submit
                e2e = now - req.t_submit
                if self.metrics is not None:
                    self._h_queue.observe(queue_wait)
                    self._h_ttft.observe(ttft)
                    self._h_e2e.observe(e2e)
                    self._c_requests.inc()
                if self.trace is not None:
                    self.trace.emit("retire", rid=req.rid,
                                    client=req.client_id,
                                    tokens=len(seq.generated),
                                    queue_wait_s=queue_wait, ttft_s=ttft,
                                    e2e_s=e2e, version=seq.version)
                if seq.degraded:
                    self.degraded_served += 1
                    if self.metrics is not None:
                        self._c_degraded.inc()
                self.finished[req.rid] = {
                    "client_id": req.client_id,
                    "tokens": np.asarray(seq.generated, np.int32),
                    "version": seq.version,
                    "degraded": seq.degraded,
                    "deadline_exceeded": seq.deadline_hit}

    def run(self, max_steps=10_000):
        """Drive ``step()`` until queue and batch drain; returns report."""
        steps = 0
        while not self.scheduler.idle and steps < max_steps:
            self.step()
            steps += 1
        return self.report()

    def _latency_stats(self):
        """Latency percentile keys for ``report()``, read off the obs
        histograms (windowed: ``reset_stats()`` clears them, so a timed
        pass is not polluted by warm-up). All None when metrics are off
        or the window is empty — report consumers must handle null."""
        out = {}
        pairs = (("queue_wait", "_h_queue"), ("ttft", "_h_ttft"),
                 ("intertoken", "_h_itl"), ("e2e", "_h_e2e"))
        for key, attr in pairs:
            h = getattr(self, attr) if self.metrics is not None else None
            snap = h.snapshot() if h is not None and h.count else None
            for stat in ("p50", "p90", "p99", "mean"):
                out[f"{key}_{stat}_s"] = snap[stat] if snap else None
        return out

    def report(self):
        dt = (time.perf_counter() - self._t0) if self._t0 else None
        total = self.decoded_tokens + self.prefill_tokens
        generated = self.decoded_tokens + self.prefilled_requests
        steps = self.decode_steps
        rs = self.registry.stats
        return {
            "requests": len(self.finished),
            # prefill_tokens counts every prompt token processed (NOT one
            # per request); tokens = prompt + decode tokens processed.
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decoded_tokens,
            "generated_tokens": generated,
            "tokens": total,
            # rates and ratios are None (JSON null) when undefined — never
            # NaN, which is invalid JSON and poisons comparisons downstream
            "tok_per_s": total / dt if dt and dt > 0 else None,
            "gen_tok_per_s": generated / dt if dt and dt > 0 else None,
            "decode_tok_per_s": (self.decoded_tokens / self._decode_wall
                                 if self._decode_wall else None),
            "decode_steps": steps,
            "prefill_batches": self.prefill_batch_count,
            "prefill_retraces": self.prefill_retraces,
            "decode_retraces": self.decode_retraces,
            # fused-loop observability: how often the host had to sync
            # per generated token (1.0 under per-tick; ~1/T fused), how
            # many ticks each fused scan actually ran, and how the
            # T-tick page windows compared to what the scans wrote
            "host_syncs": self.host_syncs,
            "host_syncs_per_token": (self.host_syncs / self.decoded_tokens
                                     if self.decoded_tokens else None),
            "fused_scans": self.fused_scans,
            "fused_ticks_mean": (self.fused_ticks / self.fused_scans
                                 if self.fused_scans else 0.0),
            "fused_tick_shrinks": self.fused_tick_shrinks,
            "pages_window_reserved": self._pages_window_reserved,
            "pages_window_used": self._pages_window_used,
            "batch_occupancy": self._occ_sum / steps if steps else 0.0,
            "page_utilization": (self._page_util_sum / steps
                                 if steps and self.pool is not None
                                 else None),
            "pool_occupancy": (self._pool_occ_sum / steps
                               if steps and self.pool is not None
                               else None),
            "adapter_hit_rate": rs["hit_rate"],
            # adapter tiering (repro.serving.store): where HBM misses
            # were served from, what the prefetcher promoted, and the
            # current per-tier occupancy
            "tier_host_hits": rs.get("tier_host_hits", 0),
            "tier_cold_misses": rs.get("tier_cold_misses", 0),
            "host_hit_rate": rs.get("host_hit_rate"),
            "tier_promotions": rs.get("promotions", 0),
            "tier_demotions": rs.get("demotions", 0),
            "prefetches": rs.get("prefetches", 0),
            "tier_prestages": rs.get("tier_prestages", 0),
            "tier_occupancy": rs.get("tier_occupancy"),
            # prefix cache (repro.serving.prefix; zeros/None when off)
            "prefix_hits": self.scheduler.prefix_hits,
            "prefix_hit_rate": (self.scheduler.prefix_hits
                                / self.scheduler.prefix_lookups
                                if self.scheduler.prefix_lookups else None),
            "prefix_hit_tokens": self.scheduler.prefix_hit_tokens,
            "pages_shared": self.scheduler.pages_shared,
            "cow_copies": self.cow_copies,
            "prefix_evictions": (self.prefix.evictions
                                 if self.prefix is not None else 0),
            "prefix_entries": (len(self.prefix)
                               if self.prefix is not None else 0),
            # robustness accounting: every submitted request is exactly
            # one of finished (incl. deadline-retired), shed, or still
            # in flight — serving_chaos.py asserts the identity
            "shed_requests": self.scheduler.shed,
            "deadline_retired": self.deadline_retired,
            "degraded_served": self.degraded_served,
            "kv_layout": self.kv_layout,
            "lora_backend": self.lora_backend,
            "attn_backend": self.attn_backend,
            "decode_backend": self.decode_backend,
            "decode_ticks": (self.decode_ticks
                             if self.decode_backend == "fused" else 1),
            "registry_mode": getattr(self.registry, "mode", "fedsa"),
            # mesh sharding (repro.serving.sharded; zeros/None unsharded)
            "sharded": self.mesh is not None,
            "mesh_shape": ((self.mesh.shape["data"],
                            self.mesh.shape["model"])
                           if self.mesh is not None else None),
            "collective_flips": self.collective_flips,
            "cross_shard_allocs": (self.pool.cross_shard_allocs
                                   if self.pool is not None else None),
            # live refresh (versioned registry; zeros on plain engines)
            "adapter_version": getattr(self.registry, "version", 0),
            "flips": getattr(self.registry, "flips", 0),
            "deferred_flips": getattr(self.registry, "deferred_flips", 0),
            "publishes": getattr(self.registry, "publishes", 0),
            # staleness: rounds behind the committed version, summed over
            # active rows at every decode tick (per-tenant max alongside)
            "staleness_mean": (self._stale_sum / self._stale_rows
                               if self._stale_rows else 0.0),
            "staleness_max": self._stale_max,
            "tenant_staleness": dict(self._tenant_stale),
            "wall_s": dt,
            # per-request latency percentiles (repro.obs histograms)
            **self._latency_stats(),
        }
