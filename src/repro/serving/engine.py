"""ServingEngine: registry + scheduler + model → one decode loop.

``step()`` interleaves prefill and decode the way a continuous-batching
server does:

  1. admit queued requests into free batch rows (registry pins a slot),
  2. prefill each new request at batch 1 and scatter its KV row into the
     shared fixed-shape decode cache,
  3. run ONE grouped decode step for the whole mixed-client batch — the
     per-row B_i is gathered from the registry slot tables inside the
     jitted step (the grouped branch of ``lora_delta``; the fused TPU
     form of the same contraction is ``repro.kernels.bgmv``),
  4. retire finished rows, freeing their row + registry pin.

The decode step is jitted once: slot tables, slot ids, tokens, positions
and cache are all traced arguments with fixed shapes. Per-row positions
let rows sit at different sequence depths (``decode_step`` already takes
``pos: (B,)``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import decode_step, init_cache, prefill, segments
from repro.serving.registry import gather_adapters
from repro.serving.scheduler import Scheduler


def _scatter_row(big, small, row):
    """Insert a batch-1 cache pytree into row ``row`` of the batch cache.
    Every non-hybrid cache leaf carries batch at axis 1: (n, B, ...)."""
    def one(dst, src):
        start = (0, row) + (0,) * (dst.ndim - 2)
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)
    return jax.tree_util.tree_map(one, big, small)


class ServingEngine:
    def __init__(self, cfg, params, acfg, registry, *, max_batch=8,
                 max_seq=64, cache_dtype=jnp.float32):
        if cfg.family == "hybrid":
            raise NotImplementedError(
                "hybrid cache layout (inner axis before batch) not wired")
        if any(s["kind"] == "dec_attn" for s in segments(cfg)):
            raise NotImplementedError("enc-dec serving needs frame plumbing")
        if cfg.mla is not None:
            raise NotImplementedError(
                "MLA decode merges W+ΔW via effective_weight, which has no "
                "grouped per-row-B form yet")
        self.cfg, self.params, self.acfg = cfg, params, acfg
        self.registry = registry
        self.scheduler = Scheduler(max_batch)
        self.max_batch, self.max_seq = max_batch, max_seq
        self.cache = init_cache(cfg, max_batch, max_seq, cache_dtype)
        self._toks = np.zeros((max_batch, 1), np.int32)
        self._pos = np.zeros((max_batch,), np.int32)
        self._slots = np.zeros((max_batch,), np.int32)
        self.finished = {}              # rid → dict(client_id, tokens)
        self.decoded_tokens = 0
        self.prefill_tokens = 0
        self.decode_steps = 0
        self._occ_sum = 0.0
        self._t0 = None
        local = registry.local_tree

        def _adapters(tree):
            # registry templates are either the adapters tree itself or a
            # full trainables tree ({"adapters": ..., "cls_head": ...})
            return tree["adapters"] if "adapters" in tree else tree

        def _prefill_fn(tables, slot, tokens):
            ad = _adapters(gather_adapters(tables, local, slot[None]))
            logits, cache1, _ = prefill(cfg, params, ad, acfg, tokens,
                                        max_seq, cache_dtype=cache_dtype)
            return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), cache1

        def _decode_fn(tables, slots, toks, pos, cache):
            ad = _adapters(gather_adapters(tables, local, slots))
            logits, cache = decode_step(cfg, params, ad, acfg, toks, pos,
                                        cache)
            return jnp.argmax(logits[:, 0], -1).astype(jnp.int32), cache

        # prefill retraces per distinct prompt length; decode compiles once
        self._prefill = jax.jit(_prefill_fn)
        self._decode = jax.jit(_decode_fn)
        self._scatter = jax.jit(_scatter_row)

    def reset_stats(self):
        """Zero throughput counters (e.g. after a warm-up pass); keeps the
        compiled functions, cache buffers, and registry residency."""
        self.finished = {}
        self.decoded_tokens = self.prefill_tokens = self.decode_steps = 0
        self._occ_sum = 0.0
        self._t0 = None
        self.registry.hits = self.registry.misses = 0
        self.registry.evictions = 0

    # -- request plane ------------------------------------------------------
    def submit(self, client_id, prompt, max_new_tokens=16):
        assert len(prompt) + max_new_tokens <= self.max_seq, \
            "prompt + generation exceeds engine max_seq"
        return self.scheduler.submit(client_id, prompt, max_new_tokens)

    # -- serving loop -------------------------------------------------------
    def step(self):
        """One scheduler tick: admit/prefill new requests, decode one token
        for every active row, retire finished sequences."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        for seq in self.scheduler.admit(self.registry):
            row, req = seq.row, seq.request
            tok0, cache1 = self._prefill(
                self.registry.tables, jnp.int32(seq.slot),
                jnp.asarray(req.prompt[None]))
            self.cache = self._scatter(self.cache, cache1, row)
            first = int(tok0[0])
            seq.generated.append(first)
            self.prefill_tokens += 1
            self._toks[row, 0] = first
            self._pos[row] = seq.pos
            self._slots[row] = seq.slot
        self._retire_done()
        if self.scheduler.active:
            out, self.cache = self._decode(
                self.registry.tables, jnp.asarray(self._slots),
                jnp.asarray(self._toks), jnp.asarray(self._pos), self.cache)
            out = np.asarray(out)
            for row, seq in list(self.scheduler.active.items()):
                tok = int(out[row])
                seq.generated.append(tok)
                seq.pos += 1
                self._toks[row, 0] = tok
                self._pos[row] = seq.pos
                self.decoded_tokens += 1
            self.decode_steps += 1
            self._occ_sum += self.scheduler.occupancy
            self._retire_done()

    def _retire_done(self):
        for row, seq in list(self.scheduler.active.items()):
            if seq.done:
                self.scheduler.retire(row, self.registry)
                req = seq.request
                self.finished[req.rid] = {
                    "client_id": req.client_id,
                    "tokens": np.asarray(seq.generated, np.int32)}

    def run(self, max_steps=10_000):
        """Drive ``step()`` until queue and batch drain; returns report."""
        steps = 0
        while not self.scheduler.idle and steps < max_steps:
            self.step()
            steps += 1
        return self.report()

    def report(self):
        dt = (time.perf_counter() - self._t0) if self._t0 else float("nan")
        total = self.decoded_tokens + self.prefill_tokens
        return {
            "requests": len(self.finished),
            "tokens": total,
            "tok_per_s": total / dt if dt and dt > 0 else float("nan"),
            "decode_steps": self.decode_steps,
            "batch_occupancy": (self._occ_sum / self.decode_steps
                                if self.decode_steps else 0.0),
            "adapter_hit_rate": self.registry.stats["hit_rate"],
            "wall_s": dt,
        }
