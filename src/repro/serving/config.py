"""ServingConfig: the one frozen object that configures a ServingEngine.

Before this module the engine took 17 loose keyword arguments and every
call site (launcher, benchmarks, examples, tests) re-threaded them by
hand. ``ServingConfig`` consolidates them with all cross-field
validation in ``__post_init__`` — an invalid combination fails at
construction, before any device buffer is allocated — and
``from_args`` maps an argparse namespace to the dataclass in one place.

Engine construction is ``ServingEngine(cfg, params, acfg, registry,
config=ServingConfig(...))``. Passing the old loose kwargs still works
for one release: the engine folds them into a config and emits a
``DeprecationWarning`` (see ``ServingEngine.__init__``).

The three tiering knobs (``host_ring_slots``, ``cold_dir``,
``prefetch_lookahead``) configure the hierarchical adapter store —
HBM slot tables → pinned-host-RAM ring → cold npz store — described in
``repro.serving.store`` and docs/serving.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

_KV_LAYOUTS = ("auto", "paged", "dense")
_ATTN_BACKENDS = ("xla", "pallas")
_LORA_BACKENDS = ("jnp", "bgmv", "sgmv")
_DECODE_BACKENDS = ("per-tick", "fused")


def _choice(name, value, choices):
    if value not in choices:
        raise ValueError(f"{name}={value!r}: must be one of {choices}")


def _nonnegative_or_none(name, value):
    if value is not None and value < 0:
        raise ValueError(f"{name}={value!r}: must be >= 0 (or None); "
                         "0 means immediately")


def parse_mesh_shape(text):
    """``--mesh-shape``'s "DATAxMODEL" string (e.g. "4x2") → (4, 2)."""
    parts = text.lower().split("x")
    try:
        shape = tuple(int(p) for p in parts)
    except ValueError:
        shape = ()
    if len(shape) != 2:
        raise ValueError(f"--mesh-shape {text!r}: expected DATAxMODEL, "
                         "e.g. 4x1 or 2x2")
    return shape


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Every engine knob in one validated, hashable, frozen value.

    Grouped the way the engine consumes them:

    batch/cache geometry
      ``max_batch``   decode batch rows
      ``max_seq``     prompt + generation budget per row
      ``cache_dtype`` KV cache dtype

    KV layout
      ``kv_layout``   "auto" | "paged" | "dense" ("auto" resolves
                      against the model config at engine construction)
      ``page_size``   tokens per KV page (power of two)
      ``n_pages``     pool size; None = worst case + write-off page

    compute backends
      ``attn_backend``   "xla" | "pallas"
      ``lora_backend``   "jnp" | "bgmv" | "sgmv"
      ``decode_backend`` "per-tick" | "fused"
      ``decode_ticks``   max ticks per fused scan
      ``eos_id``         early-stop token id (None = generate to budget)

    robustness (docs/robustness.md)
      ``max_queue``          bound on the admission queue (None = ∞)
      ``request_deadline_s`` submit→retire budget (None = none)
      ``degrade_after_s``    base-model fallback patience (None = off)

    adapter tiering (repro.serving.store; docs/serving.md)
      ``host_ring_slots``    pinned-host-RAM ring capacity in adapters;
                             None = unbounded host tier (no cold
                             demotion — the pre-tiering behavior),
                             0 = everything lives in the cold tier
      ``cold_dir``           cold-store directory (npz per client);
                             None = in-memory cold tier
      ``prefetch_lookahead`` queued admits whose adapters the engine
                             prefetches host-ward each tick (0 = off)

    prefix cache (repro.serving.prefix; docs/serving.md §7)
      ``prefix_cache``       reuse KV pages across rows whose (adapter
                             bytes, token prefix) match — suffix-only
                             prefill + copy-on-write decode. Paged
                             layout only; rejected with dense or
                             sharded serving
      ``prefix_chunk_pages`` pages per cached chunk (>= 1): smaller
                             chunks match more, larger chunks hash less

    mesh sharding (repro.serving.sharded; docs/serving.md)
      ``shard_serving``      partition the engine over a ("data",
                             "model") device mesh: base weights
                             tensor-parallel, KV pool + decode rows
                             batch-sharded, refresh flips verified by a
                             mesh-wide collective
      ``mesh_shape``         (data, model) extents; None = all visible
                             devices on the data axis. The data extent
                             must divide ``max_batch`` (decode rows
                             split evenly across row shards).
    """

    max_batch: int = 8
    max_seq: int = 64
    cache_dtype: Any = jnp.float32
    kv_layout: str = "auto"
    page_size: int = 16
    n_pages: int | None = None
    attn_backend: str = "xla"
    lora_backend: str = "jnp"
    decode_backend: str = "per-tick"
    decode_ticks: int = 8
    eos_id: int | None = None
    max_queue: int | None = None
    request_deadline_s: float | None = None
    degrade_after_s: float | None = None
    host_ring_slots: int | None = None
    cold_dir: str | None = None
    prefetch_lookahead: int = 0
    prefix_cache: bool = False
    prefix_chunk_pages: int = 1
    shard_serving: bool = False
    mesh_shape: tuple | None = None

    def __post_init__(self):
        _choice("kv_layout", self.kv_layout, _KV_LAYOUTS)
        _choice("attn_backend", self.attn_backend, _ATTN_BACKENDS)
        _choice("lora_backend", self.lora_backend, _LORA_BACKENDS)
        _choice("decode_backend", self.decode_backend, _DECODE_BACKENDS)
        if self.max_batch < 1:
            raise ValueError(f"max_batch={self.max_batch}: need >= 1")
        if self.max_seq < 1:
            raise ValueError(f"max_seq={self.max_seq}: need >= 1")
        if self.decode_ticks < 1:
            raise ValueError(f"decode_ticks={self.decode_ticks}: need >= 1")
        if self.page_size < 1 or self.page_size & (self.page_size - 1):
            raise ValueError(f"page_size={self.page_size}: must be a "
                             "power of two")
        if self.n_pages is not None:
            if self.kv_layout == "dense":
                raise ValueError("n_pages is a paged-layout knob; "
                                 "kv_layout='dense' has no page pool")
            if self.n_pages < 2:
                raise ValueError(f"n_pages={self.n_pages}: the pool needs "
                                 "at least one page beyond the write-off")
        if self.kv_layout == "dense" and self.attn_backend == "pallas":
            raise ValueError("attn_backend='pallas' is the paged decode "
                             "kernel; the dense layout always runs xla")
        if self.max_queue is not None and self.max_queue < 0:
            raise ValueError(f"max_queue={self.max_queue}: need >= 0 "
                             "(or None for unbounded)")
        _nonnegative_or_none("request_deadline_s", self.request_deadline_s)
        _nonnegative_or_none("degrade_after_s", self.degrade_after_s)
        if self.host_ring_slots is not None and self.host_ring_slots < 0:
            raise ValueError(f"host_ring_slots={self.host_ring_slots}: "
                             "need >= 0 (or None for unbounded)")
        if self.prefetch_lookahead < 0:
            raise ValueError(f"prefetch_lookahead="
                             f"{self.prefetch_lookahead}: need >= 0")
        if (self.prefetch_lookahead > 0 and self.host_ring_slots is None
                and self.cold_dir is None):
            raise ValueError("prefetch_lookahead without a tiered store "
                             "(host_ring_slots/cold_dir both unset) can "
                             "never promote anything — set a tier bound "
                             "or drop the lookahead")
        if self.prefix_chunk_pages < 1:
            raise ValueError(f"prefix_chunk_pages="
                             f"{self.prefix_chunk_pages}: need >= 1")
        if self.prefix_cache:
            if self.kv_layout == "dense":
                raise ValueError("prefix_cache shares physical KV pages "
                                 "via the block table; kv_layout='dense' "
                                 "has no pages to share")
            if self.shard_serving:
                raise ValueError(
                    "prefix_cache with shard_serving=True is not "
                    "supported: a cached prefix admitted on another row "
                    "shard would reference foreign page-shard KV, "
                    "breaking the shard-local page locality the mesh "
                    "layout depends on")
        if self.mesh_shape is not None and not self.shard_serving:
            raise ValueError(f"mesh_shape={self.mesh_shape} without "
                             "shard_serving=True — a mesh shape only "
                             "means something on a sharded engine")
        if self.shard_serving:
            if self.attn_backend == "pallas":
                raise ValueError(
                    "shard_serving with attn_backend='pallas': the paged "
                    "attention kernel is not shard_map-aware — run the "
                    "xla block-table path on a mesh")
            if self.mesh_shape is not None:
                shape = self.mesh_shape
                if (len(shape) != 2
                        or any(not isinstance(s, int) or s < 1
                               for s in shape)):
                    raise ValueError(
                        f"mesh_shape={shape!r}: need two positive ints "
                        "(data, model)")
                if self.max_batch % shape[0] != 0:
                    raise ValueError(
                        f"mesh_shape={shape}: data axis {shape[0]} must "
                        f"divide max_batch={self.max_batch} — decode "
                        "rows split evenly across row shards")

    @property
    def tiered(self):
        """True when the config asks for a bounded/tiered adapter store."""
        return self.host_ring_slots is not None or self.cold_dir is not None

    def replace(self, **changes):
        """A copy with fields replaced (revalidates via __post_init__)."""
        return dataclasses.replace(self, **changes)

    @classmethod
    def from_args(cls, ns, **overrides):
        """Build from an argparse namespace (the launcher's flags).

        Maps each serving flag to its field; flags absent from the
        namespace keep their defaults, and ``overrides`` win over both
        (``from_args(ns, max_batch=4)``). This is the ONE place flag
        names meet field names.
        """
        mapping = {
            "max_batch": "max_batch",
            "max_seq": "max_seq",
            "kv_layout": "kv_layout",
            "page_size": "page_size",
            "n_pages": "n_pages",
            "attn_backend": "attn_backend",
            "lora_backend": "lora_backend",
            "decode_backend": "decode_backend",
            "decode_ticks": "decode_ticks",
            "eos_id": "eos_id",
            "max_queue": "max_queue",
            "request_deadline": "request_deadline_s",
            "degrade_after": "degrade_after_s",
            "host_ring_slots": "host_ring_slots",
            "cold_dir": "cold_dir",
            "prefetch_lookahead": "prefetch_lookahead",
            "prefix_cache": "prefix_cache",
            "prefix_chunk_pages": "prefix_chunk_pages",
            "shard_serving": "shard_serving",
            "mesh_shape": "mesh_shape",
        }
        kw = {}
        sentinel = object()
        for flag, field in mapping.items():
            v = getattr(ns, flag, sentinel)
            if v is not sentinel:
                kw[field] = v
        if isinstance(kw.get("mesh_shape"), str):
            kw["mesh_shape"] = parse_mesh_shape(kw["mesh_shape"])
        kw.update(overrides)
        return cls(**kw)

    def engine_kwargs(self):
        """The config as a plain dict (field → value) — handy for
        records/reports; NOT for re-threading into loose kwargs."""
        return dataclasses.asdict(self)


FIELD_NAMES = frozenset(f.name for f in dataclasses.fields(ServingConfig))
