"""Continuous-batching request scheduler.

A fixed decode batch of ``max_batch`` rows; a FIFO queue of
``(client_id, prompt)`` requests. Admission takes the head of the queue
whenever (a) a batch row is free and (b) the registry can pin a slot for
that client (hit, free slot, or unpinned LRU eviction). Finished
sequences release their row and registry pin, so the next ``admit`` can
refill the row mid-stream — decode never drains the whole batch to make
progress on the queue.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


@dataclasses.dataclass
class Request:
    client_id: int
    prompt: np.ndarray                 # (L,) int32 prompt token ids
    max_new_tokens: int = 16
    rid: int = -1                      # assigned on submit


@dataclasses.dataclass
class Sequence:
    """One in-flight row of the decode batch."""
    request: Request
    row: int
    slot: int
    pos: int                           # next cache write position
    generated: list = dataclasses.field(default_factory=list)

    @property
    def done(self):
        return len(self.generated) >= self.request.max_new_tokens


class Scheduler:
    def __init__(self, max_batch):
        self.max_batch = max_batch
        self.queue = deque()
        self.active = {}               # row → Sequence
        self._free_rows = list(range(max_batch))[::-1]
        self._next_rid = 0

    def submit(self, client_id, prompt, max_new_tokens=16):
        req = Request(client_id, np.asarray(prompt, np.int32),
                      max_new_tokens, rid=self._next_rid)
        self._next_rid += 1
        self.queue.append(req)
        return req.rid

    def admit(self, registry):
        """Move queue heads into free rows while registry slots pin.
        Returns the newly admitted Sequences (prefill still pending)."""
        admitted = []
        while self.queue and self._free_rows:
            req = self.queue[0]
            slot = registry.acquire(req.client_id)
            if slot is None:           # every slot pinned by active rows
                break
            self.queue.popleft()
            row = self._free_rows.pop()
            seq = Sequence(req, row, slot, pos=len(req.prompt))
            self.active[row] = seq
            admitted.append(seq)
        return admitted

    def retire(self, row, registry):
        """Free a finished row + its registry pin; returns the Sequence."""
        seq = self.active.pop(row)
        registry.release(seq.request.client_id)
        self._free_rows.append(row)
        return seq

    @property
    def occupancy(self):
        return len(self.active) / self.max_batch

    @property
    def idle(self):
        return not self.queue and not self.active
