"""Continuous-batching request scheduler + KV page bookkeeping.

A fixed decode batch of ``max_batch`` rows; a FIFO queue of
``(client_id, prompt)`` requests. Admission takes the head of the queue
whenever (a) a batch row is free, (b) the registry can pin a slot for
that client (hit, free slot, or unpinned LRU eviction), and — under the
paged KV layout — (c) the ``PagePool`` can reserve enough pages for
``prompt + max_new_tokens``. One registry pin covers EVERY slot table
the mode packs (B only under FedSA; the paired A and B tables under
per-client-A packing — a single slot index addresses the pair, so a
pinned tenant's matrices can never be torn apart by eviction). Finished
sequences release their row, registry pin, and pages, so the next
``admit`` can refill the row mid-stream — decode never drains the whole
batch to make progress on the queue.

The scheduler owns the **block table**: a ``(max_batch, P)`` int32 array
mapping each row's logical page index to a physical page of the pool.
Rows without a sequence (and logical pages past a sequence's
reservation) point at physical page 0, the pool's *write-off page* —
writes land there harmlessly and reads are masked by position.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np


def bucket_len(n, lo=1):
    """Smallest power-of-two >= max(n, lo) — the padding bucket, so jit
    compiles O(log max_seq) prefill variants instead of one per length."""
    b = max(int(lo), 1)
    while b < n:
        b *= 2
    return b


def prefill_batches(seqs, *, min_len):
    """Group admitted sequences into length-bucketed prefill batches.

    Returns ``[(L, [Sequence, ...]), ...]`` sorted by bucket length L
    (a power of two >= min_len, so L is a whole number of pages whenever
    min_len is the page size).
    """
    groups = {}
    for s in seqs:
        groups.setdefault(bucket_len(len(s.request.prompt), min_len),
                          []).append(s)
    return sorted(groups.items())


class PagePool:
    """Fixed pool of KV-cache pages with a free-list allocator.

    Physical page 0 is reserved as the shared write-off page (absorbs
    writes from padded prefill rows and idle decode rows); ``capacity``
    counts the allocatable pages.

    ``n_shards > 1`` splits the free list by contiguous page-id block:
    shard ``s`` owns physical pages ``[s*span, (s+1)*span)`` with
    ``span = n_pages // n_shards`` — exactly the blocks GSPMD assigns
    each "data" shard when the pool's page axis is mesh-sharded (see
    ``sharding.rules.paged_cache_specs``). ``alloc(n, shard=s)``
    prefers shard-local pages so a decode row's KV writes stay on its
    own device shard, falling back to stealing from other shards
    (counted in ``cross_shard_allocs``) rather than refusing — a steal
    costs locality, never correctness, because the block table carries
    full physical page ids either way. Shard 0's span includes the
    write-off page, so it owns one fewer allocatable page.
    """

    def __init__(self, n_pages, page_size, *, n_shards=1):
        assert page_size >= 1 and (page_size & (page_size - 1)) == 0, \
            "page_size must be a power of two"
        assert n_pages >= 2, "need at least one page beyond the write-off"
        assert n_shards >= 1 and n_pages % n_shards == 0, \
            f"n_shards={n_shards} must divide n_pages={n_pages}"
        self.n_pages, self.page_size = n_pages, page_size
        self.n_shards = n_shards
        span = n_pages // n_shards
        self._frees = [list(range(max(1, s * span), (s + 1) * span))[::-1]
                       for s in range(n_shards)]
        self._refs = {}                # page id → holder count (absent = free)
        self.cross_shard_allocs = 0    # allocs that stole >= 1 foreign page

    def pages_needed(self, n_tokens):
        return -(-n_tokens // self.page_size)

    @property
    def capacity(self):
        return self.n_pages - 1

    @property
    def free_count(self):
        return sum(len(f) for f in self._frees)

    @property
    def used_count(self):
        return self.capacity - self.free_count

    def refcount(self, page):
        """Holders of a physical page (0 = free / the write-off page)."""
        return self._refs.get(page, 0)

    def alloc(self, n, shard=0):
        """n physical page ids (shard-local first), or None if the pool
        can't cover them. Every returned page starts at refcount 1."""
        if n > self.free_count:
            return None
        pages, stole = [], False
        for src in [shard] + [s for s in range(self.n_shards) if s != shard]:
            free = self._frees[src]
            while free and len(pages) < n:
                pages.append(free.pop())
                stole |= src != shard
            if len(pages) == n:
                break
        self.cross_shard_allocs += stole
        for p in pages:
            self._refs[p] = 1
        return pages

    def share(self, pages):
        """Add a holder to already-allocated pages (prefix-cache sharing).
        Sharing a free page is a bug — the free list would hand it out
        again while the 'share' still points at it."""
        for p in pages:
            if p not in self._refs:
                raise ValueError(f"share of free page {p}")
            self._refs[p] += 1

    def release(self, pages):
        """Drop one holder per page; a page returns to the free list only
        when its last holder releases. Releasing an already-free page id
        raises — silently re-appending it would put the SAME physical
        page on the free list twice, and two later sequences would then
        scribble over each other's KV."""
        span = self.n_pages // self.n_shards
        for p in pages:
            refs = self._refs.get(p)
            if refs is None:
                raise ValueError(f"double release of page {p}")
            if refs > 1:
                self._refs[p] = refs - 1
            else:
                del self._refs[p]
                self._frees[p // span].append(p)


@dataclasses.dataclass
class Request:
    client_id: int
    prompt: np.ndarray                 # (L,) int32 prompt token ids
    max_new_tokens: int = 16
    rid: int = -1                      # assigned on submit
    t_submit: float = 0.0              # perf_counter at submit
    deadline_s: float = None           # submit→retire budget (None = ∞)


@dataclasses.dataclass
class Sequence:
    """One in-flight row of the decode batch."""
    request: Request
    row: int
    slot: int
    pos: int                           # next cache write position
    generated: list = dataclasses.field(default_factory=list)
    pages: list = dataclasses.field(default_factory=list)
    buf: int = 0                       # registry buffer at admission
    version: int = 0                   # adapter round at admission
    finished: bool = False             # early stop (engine saw eos_id)
    degraded: bool = False             # serving the base model (zero slot)
    deadline_hit: bool = False         # retired by the deadline sweep
    prefix_len: int = 0                # prompt tokens served from the cache
    prefix_ns: tuple = None            # prefix namespace (adapter identity)
    cow_stash: list = dataclasses.field(default_factory=list)
    # ^ page(s) reserved at admission for the one copy-on-write this row
    #   can ever need (its partial tail page); released at retire if unused
    # latency trace stamps (perf_counter; see repro.obs):
    t_admit: float = 0.0               # left the queue for a batch row
    t_first: float = 0.0               # first token visible on the host
    t_last: float = 0.0                # newest token visible on the host

    @property
    def budget(self):
        """Decode tokens this row may still emit."""
        return (0 if self.finished
                else self.request.max_new_tokens - len(self.generated))

    @property
    def done(self):
        return self.budget <= 0


class Scheduler:
    def __init__(self, max_batch, *, pool=None, table_pages=0, trace=None,
                 max_queue=None, degrade_after_s=None, prefix=None):
        """max_queue: bound on the waiting queue — a submit past it is
        SHED (returns None, ``request_shed`` event) instead of growing
        host memory without bound. None = unbounded (legacy behavior).
        degrade_after_s: once a queued request has waited this long for
        a registry slot (all pinned, or its client was never ingested),
        admit it on the registry's all-zeros DEGRADED slot and serve the
        base model rather than starving it. None disables degradation
        (acquire failures keep their raise/requeue semantics)."""
        self.max_batch = max_batch
        self.pool = pool
        self.trace = trace             # optional repro.obs.TraceLog
        self.max_queue = max_queue
        self.degrade_after_s = degrade_after_s
        self.prefix = prefix           # optional serving.prefix.PrefixCache
        self.queue = deque()
        self.active = {}               # row → Sequence
        self._free_rows = list(range(max_batch))[::-1]
        self._next_rid = 0
        self.shed = 0                  # requests refused or dropped unserved
        self.degraded_admits = 0
        self.prefix_lookups = 0        # paged admissions with the cache on
        self.prefix_hits = 0           # admissions that reused >= 1 page
        self.prefix_hit_tokens = 0     # prompt tokens skipped via the cache
        self.pages_shared = 0          # physical pages reused across rows
        self.block_tables = (np.zeros((max_batch, table_pages), np.int32)
                             if pool is not None else None)

    def submit(self, client_id, prompt, max_new_tokens=16, deadline_s=None):
        req = Request(client_id, np.asarray(prompt, np.int32),
                      max_new_tokens, rid=self._next_rid,
                      t_submit=time.perf_counter(), deadline_s=deadline_s)
        self._next_rid += 1
        if (self.max_queue is not None
                and len(self.queue) >= self.max_queue):
            self.shed += 1
            if self.trace is not None:
                self.trace.emit("request_shed", client=client_id,
                                reason="queue_full", rid=req.rid)
            return None
        self.queue.append(req)
        if self.trace is not None:
            self.trace.emit("submit", rid=req.rid, client=client_id)
        return req.rid

    def _shed_overdue(self):
        """Drop queued requests whose submit→retire deadline has already
        passed — they could not emit a single useful token."""
        now = time.perf_counter()
        kept = deque()
        for req in self.queue:
            if (req.deadline_s is not None
                    and now - req.t_submit > req.deadline_s):
                self.shed += 1
                if self.trace is not None:
                    self.trace.emit("request_shed", client=req.client_id,
                                    reason="deadline", rid=req.rid)
            else:
                kept.append(req)
        self.queue = kept

    def _acquire_or_degrade(self, registry, req):
        """(slot, degraded) for the queue head, or None to keep waiting.
        Degradation (serve the base model off the registry's zero slot)
        kicks in only when enabled AND the request has waited out its
        patience — a momentary all-pinned blip still resolves normally."""
        try:
            return registry.acquire(req.client_id), False
        except (RuntimeError, KeyError) as err:
            unknown = isinstance(err, KeyError)
            if self.degrade_after_s is None:
                if unknown:
                    raise               # never-ingested client: legacy raise
                return None             # all pinned: stay queued
            waited = time.perf_counter() - req.t_submit
            # an unknown client can never acquire — degrade immediately
            if not unknown and waited < self.degrade_after_s:
                return None
            slot = getattr(registry, "degraded_slot", None)
            if slot is None:           # registry without a zero slot
                if unknown:
                    raise
                return None
            self.degraded_admits += 1
            if self.trace is not None:
                self.trace.emit(
                    "degraded_serve", rid=req.rid, client=req.client_id,
                    reason="unknown_client" if unknown else "all_pinned")
            return slot, True

    def admit(self, registry):
        """Move queue heads into free rows while registry slots pin and
        (paged layout) the page pool can reserve the sequence's pages.
        Returns the newly admitted Sequences (prefill still pending)."""
        self._shed_overdue()
        admitted = []
        while self.queue and self._free_rows:
            req = self.queue[0]
            got = self._acquire_or_degrade(registry, req)
            if got is None:
                break
            slot, degraded = got
            pages, shared, stashed, matched, ns = [], [], [], 0, None
            if self.pool is not None:
                total = self.pool.pages_needed(
                    len(req.prompt) + req.max_new_tokens)
                if self.prefix is not None:
                    self.prefix_lookups += 1
                    ns = (("base",) if degraded
                          else registry.adapter_tag(req.client_id))
                    matched, shared = self.prefix.lookup(ns, req.prompt)
                    # hold this row's refs NOW so the cache eviction a few
                    # lines down can never reclaim the pages it points at
                    self.pool.share(shared)
                # one spare page for the single CoW this row can ever
                # need (its partial tail page turning shared) — reserved
                # up front so the copy can't fail under a full pool
                stash = (1 if self.prefix is not None
                         and len(req.prompt) % self.pool.page_size
                         else 0)
                private = total - len(shared)
                # rows partition over pool shards the same way GSPMD
                # blocks the batch axis: row r → shard r*S/max_batch,
                # so a sharded engine's KV writes stay shard-local
                row_hint = self._free_rows[-1]
                shard = row_hint * self.pool.n_shards // self.max_batch
                pages = self.pool.alloc(private + stash, shard=shard)
                if pages is None and self.prefix is not None:
                    # reclaim cold cached prefixes before shedding work
                    self.prefix.evict_for(self.pool, private + stash)
                    pages = self.pool.alloc(private + stash, shard=shard)
                if pages is None and (shared or stash):
                    # sharing + stash still don't fit — admit this row
                    # cache-bypass (all-private pages, never inserted, so
                    # no CoW can arise): a request the bare pool CAN hold
                    # must never wait on the cache
                    self.pool.release(shared)
                    matched, shared, ns, stash = 0, [], None, 0
                    private = total
                    pages = self.pool.alloc(total, shard=shard)
                if pages is None:      # pool exhausted: stay queued
                    self.pool.release(shared)
                    if not degraded:
                        registry.release(req.client_id)
                    if self.trace is not None:
                        self.trace.emit("pool_exhausted",
                                        client=req.client_id,
                                        needed=private + stash,
                                        free=self.pool.free_count)
                    break
                stashed = pages[private:]
                pages = shared + pages[:private]
                if matched:
                    self.prefix_hits += 1
                    self.prefix_hit_tokens += matched
                    self.pages_shared += len(shared)
                    if self.trace is not None:
                        self.trace.emit("prefix_hit", rid=req.rid,
                                        client=req.client_id,
                                        tokens=matched, pages=len(shared))
            self.queue.popleft()
            row = self._free_rows.pop()
            now = time.perf_counter()
            seq = Sequence(req, row, slot, pos=len(req.prompt), pages=pages,
                           buf=registry.retain_buffer(),
                           version=registry.version, t_admit=now,
                           degraded=degraded, prefix_len=matched,
                           prefix_ns=ns, cow_stash=stashed)
            if self.trace is not None:
                self.trace.emit("admit", rid=req.rid, client=req.client_id,
                                row=row, slot=slot,
                                queue_wait_s=now - req.t_submit)
            if self.pool is not None:
                self.block_tables[row] = 0
                self.block_tables[row, :len(pages)] = pages
            self.active[row] = seq
            admitted.append(seq)
        return admitted

    def retire(self, row, registry):
        """Free a finished row + its registry pin, buffer hold + pages.
        Page release is a refcounted recycle: pages the prefix cache (or
        a sibling row) still holds merely drop this row's reference."""
        seq = self.active.pop(row)
        if not seq.degraded:           # degraded rows never pinned a slot
            registry.release(seq.request.client_id)
        registry.release_buffer(seq.buf)
        if self.pool is not None:
            self.pool.release(seq.pages + seq.cow_stash)
            seq.pages, seq.cow_stash = [], []
            self.block_tables[row] = 0
        self._free_rows.append(row)
        return seq

    @property
    def occupancy(self):
        return len(self.active) / self.max_batch

    @property
    def idle(self):
        return not self.queue and not self.active
