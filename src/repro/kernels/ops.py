"""jit'd public wrappers for the Pallas kernels.

On a TPU backend the kernels run compiled; on CPU (this container) they run
in ``interpret=True`` mode — the kernel body executes in Python with the
same block decomposition, which is what the correctness tests sweep.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.bgmv import bgmv as _bgmv
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.lora_matmul import lora_matmul as _lora
from repro.kernels.paged_attention import paged_attention as _paged
from repro.kernels.sgmv import sgmv as _sgmv
from repro.kernels.ssm_scan import ssm_scan as _ssm
from repro.kernels.ssd_scan import ssd_scan_fused as _ssd_fused
from repro.kernels.ssm_scan import ssm_scan_fused as _ssm_fused


def _interpret_default():
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("scaling", "bm", "bn", "bk",
                                             "interpret"))
def lora_matmul(x, w, a, b, scaling=1.0, *, bm=256, bn=256, bk=512,
                interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _lora(x, w, a, b, scaling, bm=bm, bn=bn, bk=bk,
                 interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scaling", "bm", "bn", "bk",
                                             "interpret"))
def bgmv(x, w, a, b_slots, slot_ids, scaling=1.0, *, bm=256, bn=256,
         bk=512, interpret=None):
    """Multi-tenant grouped LoRA matmul (shared Ā, per-row B[slot])."""
    interpret = _interpret_default() if interpret is None else interpret
    return _bgmv(x, w, a, b_slots, slot_ids, scaling, bm=bm, bn=bn, bk=bk,
                 interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scaling", "bm", "bn", "bk",
                                             "interpret"))
def sgmv(x, w, a_slots, b_slots, slot_ids, scaling=1.0, *, bm=256, bn=256,
         bk=512, interpret=None):
    """Generic grouped LoRA matmul (per-row A[slot] AND B[slot])."""
    interpret = _interpret_default() if interpret is None else interpret
    return _sgmv(x, w, a_slots, b_slots, slot_ids, scaling, bm=bm, bn=bn,
                 bk=bk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bd", "chunk", "interpret"))
def ssm_scan(a, b, c, *, bd=512, chunk=64, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _ssm(a, b, c, bd=bd, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bd", "chunk", "interpret"))
def ssm_scan_fused(dt, x, bm, c, A, *, bd=512, chunk=64, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _ssm_fused(dt, x, bm, c, A, bd=bd, chunk=chunk,
                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bh", "chunk", "interpret"))
def ssd_scan_fused(dt, x, bm, c, A, *, bh=8, chunk=64, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _ssd_fused(dt, x, bm, c, A, bh=bh, chunk=chunk,
                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention(q, k_pages, v_pages, block_tables, pos, k_new=None,
                    v_new=None, *, window=None, interpret=None):
    """Paged grouped decode attention (block-table gather in-kernel;
    optional in-kernel append of the current token's K/V row)."""
    interpret = _interpret_default() if interpret is None else interpret
    return _paged(q, k_pages, v_pages, block_tables, pos, k_new, v_new,
                  window=window, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bkv",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, bq=512, bkv=512,
                    interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, window=window, bq=bq, bkv=bkv,
                  interpret=interpret)
