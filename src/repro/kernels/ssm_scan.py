"""Mamba1 selective-scan kernels with VMEM-resident state.

Two variants:

``ssm_scan``        takes precomputed decay/input tensors a, b (rank 4) —
                    the reference-shaped kernel.
``ssm_scan_fused``  takes the RAW projections (dt, x, B, C, A) and forms
                    a_t = exp(dt_t·A), b_t = (dt_t·x_t)⊗B_t INSIDE the
                    kernel — the production form: HBM traffic is one read
                    of the rank-3 inputs and one write of y; the rank-4
                    tensors and the (bd, N) state never touch HBM. This is
                    the TPU adaptation of the Mamba CUDA kernel's
                    shared-memory-resident recurrence (DESIGN.md §3.2),
                    and the §Perf iteration-2 fix for falcon-mamba's
                    memory-bound prefill.

Grid (B, D/bd, S/chunk): the chunk axis is sequential ("arbitrary"); the
(bd, N) state lives in VMEM scratch persisted across chunk steps. Inside a
chunk the recurrence is a fori_loop of fused multiply-adds on the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(a_ref, b_ref, c_ref, y_ref, h_ref, *, chunk):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)            # (chunk, bd, N)
    b = b_ref[0].astype(jnp.float32)
    c = c_ref[0].astype(jnp.float32)            # (chunk, N)

    def step(t, h):
        h = a[t] * h + b[t]                     # (bd, N)
        y_ref[0, t] = jnp.sum(h * c[t][None, :], axis=-1).astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


def ssm_scan(a, b, c, *, bd=512, chunk=64, interpret=False):
    """a, b: (B, S, D, N); c: (B, S, N). Returns y (B, S, D) f32."""
    B, S, D, N = a.shape
    bd = min(bd, D)
    chunk = min(chunk, S)
    assert D % bd == 0 and S % chunk == 0, (D, S, bd, chunk)
    grid = (B, D // bd, S // chunk)
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd, N), lambda i, j, s: (i, s, j, 0)),
            pl.BlockSpec((1, chunk, bd, N), lambda i, j, s: (i, s, j, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, j, s: (i, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, bd), lambda i, j, s: (i, s, j)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, c)


def _fused_kernel(dt_ref, x_ref, bm_ref, c_ref, a_ref, y_ref, hout_ref,
                  h_ref, *, chunk, ns):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    dt = dt_ref[0].astype(jnp.float32)          # (chunk, bd)
    x = x_ref[0].astype(jnp.float32)            # (chunk, bd)
    bm = bm_ref[0].astype(jnp.float32)          # (chunk, N)
    c = c_ref[0].astype(jnp.float32)            # (chunk, N)
    A = a_ref[...].astype(jnp.float32)          # (bd, N)

    def step(t, h):
        a_t = jnp.exp(dt[t][:, None] * A)               # (bd, N)
        b_t = (dt[t] * x[t])[:, None] * bm[t][None, :]  # (bd, N)
        h = a_t * h + b_t
        y_ref[0, t] = jnp.sum(h * c[t][None, :], axis=-1).astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])

    @pl.when(s == ns - 1)
    def _final():
        hout_ref[0] = h_ref[...]


def ssm_scan_fused(dt, x, bm, c, A, *, bd=512, chunk=64, interpret=False):
    """dt, x: (B, S, D); bm, c: (B, S, N); A: (D, N).

    Returns (y (B, S, D) f32, final state (B, D, N) f32). Decay a_t and
    input b_t are formed in VMEM — HBM traffic is exactly one read of
    (dt, x, bm, c) and one write of (y, h_final).
    """
    B, S, D = dt.shape
    N = bm.shape[-1]
    bd = min(bd, D)
    chunk = min(chunk, S)
    assert D % bd == 0 and S % chunk == 0, (D, S, bd, chunk)
    ns = S // chunk
    grid = (B, D // bd, ns)
    return pl.pallas_call(
        functools.partial(_fused_kernel, chunk=chunk, ns=ns),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((1, chunk, bd), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((1, chunk, N), lambda i, j, s: (i, s, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, j, s: (i, s, 0)),
            pl.BlockSpec((bd, N), lambda i, j, s: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((1, bd, N), lambda i, j, s: (i, j, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, S, D), jnp.float32),
                   jax.ShapeDtypeStruct((B, D, N), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(dt, x, bm, c, A)
