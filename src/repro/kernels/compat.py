"""jax version compat for Pallas-TPU symbols.

jax renamed ``pltpu.TPUCompilerParams`` → ``pltpu.CompilerParams``; the
toolchain baked into this container (0.4.x) still ships the old name.
Every kernel imports ``CompilerParams`` from here so both spellings work.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
