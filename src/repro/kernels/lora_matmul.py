"""Fused LoRA matmul kernel: y = x·W + s·(x·A)·B in ONE pass over x.

The unfused form launches three matmuls and round-trips the rank-r
intermediate h = x·A through HBM. Fused, h lives in a VMEM scratch
accumulator: per (m, n) output tile we stream K-blocks of x once, feeding
BOTH the base accumulation and the A-projection; the rank-r correction is
applied when the K-loop finishes. Arithmetic intensity of the LoRA path
rises from ~r FLOP/byte to ~bm FLOP/byte.

Tiling: grid (M/bm, N/bn, K/bk), K sequential ("arbitrary"); MXU-aligned
block shapes (multiples of 128 on the matmul dims). Scratch:
acc (bm, bn) f32 + h (bm, r) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, h_ref, *,
            scaling, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...],
                            preferred_element_type=jnp.float32)
    h_ref[...] += jnp.dot(x, a_ref[...],
                          preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        delta = jnp.dot(h_ref[...].astype(b_ref.dtype), b_ref[...],
                        preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scaling * delta).astype(o_ref.dtype)


def lora_matmul(x, w, a, b, scaling, *, bm=256, bn=256, bk=512,
                interpret=False):
    """x: (M, K); w: (K, N); a: (K, r); b: (r, N) → (M, N)."""
    M, K = x.shape
    N = w.shape[1]
    r = a.shape[1]
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, scaling=scaling, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, r), lambda i, j, k: (k, 0)),
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, r), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, a, b)
