"""Grouped (batched-gather) LoRA matmul for multi-tenant FedSA serving:

  y[m] = x[m]·W + s·(x[m]·Ā)·B[slot[m]]

One decode batch mixes rows from many tenants. Generic multi-LoRA SGMV
must gather BOTH A_i and B_i per row; FedSA-LoRA's invariant — the
aggregated Ā is *batch-global*, only B_i is per-client — lets the rank-r
projection h = x·Ā run once per (m, k) tile on the MXU exactly like the
fused ``lora_matmul``. Only the final rank-r → N expansion is per-row.

The per-row gather is expressed as a matmul (MXU-friendly, no dynamic
VMEM indexing): with P the (bm, n_slots) one-hot of slot ids, the
slot-routed correction is

  delta = reshape(P[:, :, None] * h[:, None, :], (bm, S·r)) @ B_flat

where B_flat is the (n_slots·r, N) flattened slot table. Cost of the
expansion grows only with n_slots·r (the *hot* adapter set, not the
tenant population), so for n_slots ≤ 64, r ≤ 16 it stays one small
matmul per output tile.

Block-shape constraints
-----------------------
Tiling mirrors ``lora_matmul``: grid (M/bm, N/bn, K/bk) with K innermost
and sequential ("arbitrary"); M, N, K must divide by the (possibly
clamped) bm/bn/bk — decode batches pad M to the block. Scratch is
acc (bm, bn) f32 + h (bm, r) f32, accumulated across K tiles and only
materialized to the output tile at k == nk - 1, so the scratch plus the
(n_slots·r, bn) B_flat block must fit VMEM (~16 MB/core). Slot ids ride
along as a (bm, 1) int32 VMEM block per M tile. For f32 operands keep
bm ≥ 8 and bn, bk multiples of 128 (lane width); n_slots·r need not be
a multiple of 128 — the compiler pads — but full-lane occupancy of the
expansion wants it to be.

When the batch's A is NOT shared (per-client A_i under FedIT/FedDPA, or
the version-indexed gather of a double-buffered registry), this kernel
does not apply — ``repro.kernels.sgmv`` generalizes the same one-hot
routing to a per-row A gather.

Validation caveat
-----------------
On this CPU container the kernel runs only in ``interpret=True`` mode
(the Python body with the same block decomposition — what the
kernel-vs-ref sweeps in ``tests/test_bgmv.py`` exercise). Real-TPU
block-shape limits, the Mosaic lowering of the one-hot expansion, and
compiled-vs-interpret numerics are unvalidated (ROADMAP "On-TPU kernel
validation").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(s_ref, x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, h_ref, *,
            scaling, nk, n_slots):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...],
                            preferred_element_type=jnp.float32)
    h_ref[...] += jnp.dot(x, a_ref[...],
                          preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        bm, r = h_ref.shape
        slots = s_ref[...][:, 0]                              # (bm,)
        onehot = (slots[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (bm, n_slots), 1)).astype(jnp.float32)
        hp = (onehot[:, :, None] * h_ref[...][:, None, :]
              ).reshape(bm, n_slots * r)
        delta = jnp.dot(hp.astype(b_ref.dtype), b_ref[...],
                        preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scaling * delta).astype(o_ref.dtype)


def bgmv(x, w, a, b_slots, slot_ids, scaling, *, bm=256, bn=256, bk=512,
         interpret=False):
    """x: (M, K); w: (K, N); a: (K, r); b_slots: (n_slots, r, N);
    slot_ids: (M,) int32 in [0, n_slots) → (M, N)."""
    M, K = x.shape
    N = w.shape[1]
    n_slots, r, _ = b_slots.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    b_flat = b_slots.reshape(n_slots * r, N)
    sids = slot_ids.astype(jnp.int32).reshape(M, 1)
    return pl.pallas_call(
        functools.partial(_kernel, scaling=scaling, nk=nk, n_slots=n_slots),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, r), lambda i, j, k: (k, 0)),
            pl.BlockSpec((n_slots * r, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, r), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(sids, x, w, a, b_flat)
