"""Mamba2 SSD fused scan kernel — per-head outer-product state in VMEM.

h_t = exp(dt_t·A_h)·h_{t-1} + (dt_t·x_t) ⊗ B_t ;  y_t = h_t · C_t

Inputs are the RAW per-head projections (dt, x, B, C, A); the rank-5
(B, S, nh, hd, ds) input tensor and the (nh, hd, ds) state are formed and
kept in VMEM (the Zamba2/Mamba2 analogue of ``ssm_scan_fused``). Grid
(B, nh/bh, S/chunk) with the chunk axis sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(dt_ref, x_ref, bm_ref, c_ref, a_ref, y_ref, hout_ref, h_ref, *,
            chunk, ns):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    dt = dt_ref[0].astype(jnp.float32)          # (chunk, bh)
    x = x_ref[0].astype(jnp.float32)            # (chunk, bh, hd)
    bm = bm_ref[0].astype(jnp.float32)          # (chunk, bh, ds)
    c = c_ref[0].astype(jnp.float32)            # (chunk, bh, ds)
    A = a_ref[...].astype(jnp.float32)          # (bh,)

    def step(t, h):
        a_t = jnp.exp(dt[t] * A)                            # (bh,)
        b_t = (dt[t][:, None] * x[t])[..., None] * bm[t][:, None, :]
        h = a_t[:, None, None] * h + b_t                    # (bh, hd, ds)
        y_ref[0, t] = jnp.sum(h * c[t][:, None, :],
                              axis=-1).astype(y_ref.dtype)  # (bh, hd)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])

    @pl.when(s == ns - 1)
    def _final():
        hout_ref[0] = h_ref[...]


def ssd_scan_fused(dt, x, bm, c, A, *, bh=8, chunk=64, interpret=False):
    """dt: (B, S, nh); x: (B, S, nh, hd); bm, c: (B, S, nh, ds); A: (nh,).

    Returns (y (B, S, nh, hd) f32, final state (B, nh, hd, ds) f32).
    """
    B, S, nh = dt.shape
    hd = x.shape[-1]
    ds = bm.shape[-1]
    bh = min(bh, nh)
    chunk = min(chunk, S)
    assert nh % bh == 0 and S % chunk == 0, (nh, S, bh, chunk)
    ns = S // chunk
    grid = (B, nh // bh, ns)
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, ns=ns),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bh), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((1, chunk, bh, hd), lambda i, j, s: (i, s, j, 0)),
            pl.BlockSpec((1, chunk, bh, ds), lambda i, j, s: (i, s, j, 0)),
            pl.BlockSpec((1, chunk, bh, ds), lambda i, j, s: (i, s, j, 0)),
            pl.BlockSpec((bh,), lambda i, j, s: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bh, hd), lambda i, j, s: (i, s, j, 0)),
            pl.BlockSpec((1, bh, hd, ds), lambda i, j, s: (i, j, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, S, nh, hd), jnp.float32),
                   jax.ShapeDtypeStruct((B, nh, hd, ds), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bh, hd, ds), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(dt, x, bm, c, A)
