"""Generic SGMV: grouped LoRA matmul with BOTH matrices gathered per row.

  y[m] = x[m]·W + s·(x[m]·A[slot[m]])·B[slot[m]]

This is the serving contraction for personal-A adapters — FedIT-style
plain LoRA and FedDPA personal pairs, where every tenant owns its own
(A_i, B_i) — and for any mixed batch that breaks FedSA-LoRA's
batch-global-Ā invariant (``repro.kernels.bgmv`` exploits that invariant
and only gathers B per row; it stays the fast path whenever Ā IS
batch-global).

One-hot-matmul expansion
------------------------
Neither gather is expressed as dynamic VMEM indexing (per-row pointer
chasing starves the MXU and Mosaic restricts dynamic indices on the
sublane axis). Instead both sides route through the slot axis
arithmetically:

  *shrink*  A_flat is the (K, S·r) concatenation of every slot's A, so
            ht = x @ A_flat projects each row against ALL S slot A's at
            once — one (bm,bk)×(bk,S·r) MXU matmul per K tile, no
            per-row selection inside the K loop;
  *select+expand*  with P the (bm, S) one-hot of slot ids, masking
            ht.reshape(bm, S, r) by P[:, :, None] zeroes every slot a
            row did not ask for. The masked (bm, S·r) block IS the
            routed input of the expansion: delta = (P⊙ht) @ B_flat with
            B_flat the (S·r, N) flattened B table — rows of B_flat
            belonging to foreign slots multiply zeros.

Cost of both sides grows with S·r (the *hot* adapter set, never the
tenant population): the shrink does S× the flops of bgmv's shared-Ā
projection, which for S ≤ 64, r ≤ 16 keeps A_flat ≤ 1024 lanes — one
MXU tile column. That S× overdraw is the price of per-row A; prefer
bgmv when the batch shares one Ā.

Block-shape constraints
-----------------------
Grid (M/bm, N/bn, K/bk) with K innermost and sequential ("arbitrary");
M, N, K must divide by the (possibly clamped) bm/bn/bk. Scratch is
acc (bm, bn) f32 + ht (bm, S·r) f32, accumulated across K tiles and
only materialized to the output tile at k == nk-1, so bm·bn + bm·S·r
f32 scratch plus the (bk, S·r) A_flat and (S·r, bn) B_flat blocks must
fit VMEM (~16 MB/core). Slot ids ride along as a (bm, 1) int32 block
per M tile. For f32 operands keep bm ≥ 8 and bn, bk multiples of 128
(lane width); S·r ideally a multiple of 128 for full-lane occupancy —
correctness does not require it, the compiler pads.

Validation caveat
-----------------
On this CPU container the kernel runs only in ``interpret=True`` mode
(the Python body with the same block decomposition — what the
kernel-vs-ref sweeps in ``tests/test_sgmv.py`` exercise). Real-TPU
block-shape limits, the Mosaic lowering of the one-hot masking, and
compiled-vs-interpret numerics are unvalidated (ROADMAP "On-TPU kernel
validation").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(s_ref, x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, ht_ref, *,
            scaling, nk, n_slots, r):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ht_ref[...] = jnp.zeros_like(ht_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...],
                            preferred_element_type=jnp.float32)
    # shrink vs EVERY slot's A at once: (bm, bk) @ (bk, S·r)
    ht_ref[...] += jnp.dot(x, a_ref[...],
                           preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        bm = ht_ref.shape[0]
        slots = s_ref[...][:, 0]                              # (bm,)
        onehot = (slots[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (bm, n_slots), 1)).astype(jnp.float32)
        # masking the per-slot shrink IS the routed expansion input
        hp = (ht_ref[...].reshape(bm, n_slots, r)
              * onehot[:, :, None]).reshape(bm, n_slots * r)
        delta = jnp.dot(hp.astype(b_ref.dtype), b_ref[...],
                        preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scaling * delta).astype(o_ref.dtype)


def sgmv(x, w, a_slots, b_slots, slot_ids, scaling, *, bm=256, bn=256,
         bk=512, interpret=False):
    """x: (M, K); w: (K, N); a_slots: (n_slots, K, r);
    b_slots: (n_slots, r, N); slot_ids: (M,) int32 in [0, n_slots)
    → (M, N)."""
    M, K = x.shape
    N = w.shape[1]
    n_slots, _, r = a_slots.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    a_flat = a_slots.transpose(1, 0, 2).reshape(K, n_slots * r)
    b_flat = b_slots.reshape(n_slots * r, N)
    sids = slot_ids.astype(jnp.int32).reshape(M, 1)
    return pl.pallas_call(
        functools.partial(_kernel, scaling=scaling, nk=nk, n_slots=n_slots,
                          r=r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, n_slots * r), lambda i, j, k: (k, 0)),
            pl.BlockSpec((n_slots * r, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, n_slots * r), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(sids, x, w, a_flat, b_flat)
