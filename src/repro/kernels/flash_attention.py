"""Blockwise (flash) causal attention kernel with online softmax.

Grid (B·H, Sq/bq, T/bkv), KV axis sequential. Running max / sum / output
accumulator live in VMEM scratch persisted across KV steps; scores are
never materialized beyond one (bq, bkv) tile. Supports causal masking and
an optional sliding window (the long_500k dense-arch variant).

q may be shorter than k/v (decode: Sq == 1 block against a long cache);
query positions are offset by T - S so the causal mask lines up.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, bq, bkv, nkv, causal, window, q_offset):
    kv = pl.program_id(2)

    @pl.when(kv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0].astype(jnp.float32)            # (bkv, d)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    qp = (pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bkv), 0) + q_offset)
    kp = kv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.ones((bq, bkv), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv == nkv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, bq=512, bkv=512,
                    interpret=False):
    """q: (B, H, S, d); k, v: (B, H, T, d) → (B, H, S, d)."""
    B, H, S, d = q.shape
    T = k.shape[2]
    bq = min(bq, S)
    bkv = min(bkv, T)
    assert S % bq == 0 and T % bkv == 0, (S, T, bq, bkv)
    qr = q.reshape(B * H, S, d)
    kr = k.reshape(B * H, T, d)
    vr = v.reshape(B * H, T, d)
    grid = (B * H, S // bq, T // bkv)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=d ** -0.5, bq=bq, bkv=bkv,
                          nkv=T // bkv, causal=causal, window=window,
                          q_offset=T - S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, d)
