"""Paged grouped decode-attention: one query token per row against a
block-table-indirected KV page pool (vLLM-style PagedAttention).

The serving engine stores K/V in fixed-size pages shared by every
sequence; a per-row block table maps logical page p of row b to the
physical page ``block_tables[b, p]``. The kernel never materializes the
gathered (B, S, Hkv, hd) view the jnp path builds: the block table and
positions ride in as *scalar-prefetch* operands
(``PrefetchScalarGridSpec``) so the K/V BlockSpec index_map dereferences
the table directly — grid cell (b, h, p) DMAs exactly one physical page
from HBM into VMEM.

Block-shape constraints
-----------------------
Grid (B, Hkv, P), page axis innermost and sequential ("arbitrary").
GQA: the G = H // Hkv query heads of one KV head share the page read;
scores are (G, page) tiles on the MXU with the same online-softmax
scratch (m, l, acc — (G, 1), (G, 1), (G, hd) f32) as
``flash_attention``. H must divide by Hkv; every row's block table must
be P entries wide (the engine truncates P to the page bucket covering
the deepest active row, never per-row). One K/V block is
(1, page, 1, hd) — page · hd · dtype bytes must fit VMEM alongside the
scratch, and hd wants to be a multiple of 128 (lane width) with
page ≥ 8 sublanes for f32 K/V. Pages wholly beyond the row's position
(or wholly outside the sliding window) are skipped with ``pl.when`` —
a row at depth t touches ceil((t+1)/page) pages, not P.

In-kernel new-token K/V append
------------------------------
With ``k_new``/``v_new`` given ((B, Hkv, hd), the current token's just-
projected row), the kernel APPENDS the row before attending: the grid
cell whose physical page holds position ``pos[b]`` overwrites offset
``pos % page`` of its VMEM-resident K/V block with the new row prior to
the score matmul. The HBM pools themselves stay read-only — the caller
still commits all layers' rows with its one post-scan scatter per pool
— but the stale/garbage slot in HBM is never attended and the pools no
longer need a pre-call ``.at[phys, off].set`` copy per layer (the old
pre-scatter path, retired). This is what lets ``decode_scan_paged`` run
multiple decode ticks on-device: tick t's append is visible to tick t's
attention in-kernel and to tick t+1's through the post-scan commit.

Validation caveat
-----------------
On this CPU container the kernel runs only in ``interpret=True`` mode
(the Python body with the same block decomposition — what the
kernel-vs-ref sweeps in ``tests/test_paged_attention.py`` exercise).
Real-TPU block-shape limits, the scalar-prefetch index_map lowering,
and the in-kernel append select are unvalidated (ROADMAP "On-TPU
kernel validation").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, *rest, page, npages,
            scale, window, append):
    if append:
        kn_ref, vn_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[b]
    base = p * page
    live = base <= pos                       # page holds positions <= pos
    if window is not None:                   # ... and inside the window
        live &= (pos - (base + page - 1)) < window

    @pl.when(live)
    def _accumulate():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)       # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if append:
            # in-kernel new-token append: the page holding pos gets the
            # current row written over offset pos % page BEFORE the
            # scores — the stale HBM slot is never attended (2-D iota:
            # TPU has no 1-D iota)
            sel = ((jax.lax.broadcasted_iota(jnp.int32, (page, 1), 0)
                    == pos % page) & (p == pos // page))
            k = jnp.where(sel, kn_ref[0, 0].astype(jnp.float32), k)
            v = jnp.where(sel, vn_ref[0, 0].astype(jnp.float32), v)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        idx = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = idx <= pos
        if window is not None:
            valid &= (pos - idx) < window
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        pexp = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(pexp, axis=-1,
                                                 keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            pexp, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(p == npages - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_tables, pos, k_new=None,
                    v_new=None, *, window=None, interpret=False):
    """q: (B, H, hd); k_pages/v_pages: (n_pages, page, Hkv, hd);
    block_tables: (B, P) int32 physical page ids; pos: (B,) int32 index
    of the newest token → (B, H, hd).

    Without ``k_new``/``v_new`` the row at ``pos`` must already live in
    its page. With them ((B, Hkv, hd)) the kernel appends the row
    in-kernel before attending (see module docstring) — the pools may
    hold stale data at ``pos`` and are never copied.
    """
    B, H, hd = q.shape
    page, Hkv = k_pages.shape[1], k_pages.shape[2]
    P = block_tables.shape[1]
    G = H // Hkv
    append = k_new is not None
    qr = q.reshape(B, Hkv, G, hd)
    kv_spec = pl.BlockSpec((1, page, 1, hd),
                           lambda b, h, p, bt, ps: (bt[b, p], 0, h, 0))
    in_specs = [
        pl.BlockSpec((1, 1, G, hd), lambda b, h, p, bt, ps: (b, h, 0, 0)),
        kv_spec,
        kv_spec,
    ]
    operands = [qr, k_pages, v_pages]
    if append:
        new_spec = pl.BlockSpec((1, 1, hd), lambda b, h, p, bt, ps: (b, h, 0))
        in_specs += [new_spec, new_spec]
        operands += [k_new.astype(k_pages.dtype), v_new.astype(v_pages.dtype)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, p, bt, ps: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, hd), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, page=page, npages=P, scale=hd ** -0.5,
                          window=window, append=append),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), pos.astype(jnp.int32), *operands)
    return out.reshape(B, H, hd)
