"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lora_matmul_ref(x, w, a, b, scaling):
    """y = x·W + s·(x·A)·B, f32 accumulation.

    x: (M, K); w: (K, N); a: (K, r); b: (r, N).
    """
    x32 = x.astype(jnp.float32)
    y = x32 @ w.astype(jnp.float32)
    h = x32 @ a.astype(jnp.float32)
    return (y + scaling * (h @ b.astype(jnp.float32))).astype(x.dtype)


def bgmv_ref(x, w, a, b_slots, slot_ids, scaling):
    """Grouped serving matmul: y[m] = x[m]·W + s·(x[m]·Ā)·B[slot[m]].

    x: (M, K); w: (K, N); a: (K, r); b_slots: (n_slots, r, N);
    slot_ids: (M,) int32.
    """
    x32 = x.astype(jnp.float32)
    y = x32 @ w.astype(jnp.float32)
    h = x32 @ a.astype(jnp.float32)                  # (M, r) — shared Ā
    bsel = b_slots.astype(jnp.float32)[slot_ids]     # (M, r, N) per-row B
    return (y + scaling * jnp.einsum("mr,mrn->mn", h, bsel)).astype(x.dtype)


def sgmv_ref(x, w, a_slots, b_slots, slot_ids, scaling):
    """Generic grouped LoRA matmul — BOTH matrices gathered per row:
    y[m] = x[m]·W + s·(x[m]·A[slot[m]])·B[slot[m]].

    x: (M, K); w: (K, N); a_slots: (n_slots, K, r);
    b_slots: (n_slots, r, N); slot_ids: (M,) int32.
    """
    x32 = x.astype(jnp.float32)
    y = x32 @ w.astype(jnp.float32)
    asel = a_slots.astype(jnp.float32)[slot_ids]     # (M, K, r) per-row A
    bsel = b_slots.astype(jnp.float32)[slot_ids]     # (M, r, N) per-row B
    h = jnp.einsum("mk,mkr->mr", x32, asel)
    return (y + scaling * jnp.einsum("mr,mrn->mn", h, bsel)).astype(x.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, pos, k_new=None,
                        v_new=None, *, window=None):
    """Paged grouped decode attention: gather pages into a logical view,
    then masked softmax over positions <= pos (and inside the window).

    q: (B, H, hd); k_pages/v_pages: (n_pages, page, Hkv, hd);
    block_tables: (B, P) int32 physical page ids; pos: (B,) int32.
    k_new/v_new ((B, Hkv, hd), optional): the current token's K/V row,
    inserted into the logical view at ``pos`` before the softmax (the
    in-kernel append path — pools may hold stale data at ``pos``).
    Returns (B, H, hd).
    """
    B, H, hd = q.shape
    page, Hkv = k_pages.shape[1], k_pages.shape[2]
    P = block_tables.shape[1]
    T = P * page
    k = k_pages[block_tables.reshape(-1)].reshape(B, T, Hkv, hd)
    v = v_pages[block_tables.reshape(-1)].reshape(B, T, Hkv, hd)
    if k_new is not None:
        bidx = jnp.arange(B)
        k = k.at[bidx, pos].set(k_new.astype(k.dtype))
        v = v.at[bidx, pos].set(v_new.astype(v.dtype))
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qg,
                   k.astype(jnp.float32)) * hd ** -0.5
    idx = jnp.arange(T)[None, :]
    valid = idx <= pos[:, None]
    if window is not None:
        valid &= (pos[:, None] - idx) < window
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def ssm_scan_ref(a, b, c):
    """Mamba1 selective scan: h_t = a_t⊙h_{t-1} + b_t; y_t = Σ_s h_t·C_t.

    a, b: (B, S, D, N); c: (B, S, N). Returns (y (B, S, D) f32,
    final state (B, D, N) f32).
    """
    def step(h, inp):
        at, bt, ct = inp
        h = at * h + bt
        return h, jnp.einsum("bdn,bn->bd", h, ct)

    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    c32 = c.astype(jnp.float32)
    h0 = jnp.zeros(a.shape[:1] + a.shape[2:], jnp.float32)
    h, y = jax.lax.scan(step, h0, (a32.swapaxes(0, 1), b32.swapaxes(0, 1),
                                   c32.swapaxes(0, 1)))
    return y.swapaxes(0, 1), h


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """Exact softmax attention. q: (B, H, S, d); k, v: (B, H, T, d)."""
    S, T = q.shape[2], k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * q.shape[-1] ** -0.5
    qp = jnp.arange(S)[:, None] + (T - S)
    kp = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
