"""Deterministic fault injection for the federation + serving stack.

Production federated fleets fail constantly — clients drop out, straggle
past the round deadline, or ship NaN/divergent A updates (the
instability mode the stabilized-FL line of work analyzes); the train→
serve bridge can stall or deliver a corrupted publish; the serving page
pool runs hot. This module makes every one of those a *first-class,
reproducible* code path:

  ``FaultPlan``      frozen, seeded description of a fault profile —
                     rates and windows, no state.
  ``FaultInjector``  draws every decision from a counter-free hash of
                     ``(seed, kind, *key)``, so the SAME plan replayed
                     against the SAME workload yields the SAME fault
                     timeline regardless of call order, thread timing,
                     or how many unrelated decisions happened in
                     between. Decisions are recorded on ``.decisions``
                     and emitted as ``fault_injected`` trace events
                     (``repro.obs``), which is what the chaos-smoke CI
                     job validates.

Fault kinds (the vocabulary, keyed deterministically):

  ``dropout``    client skips a round (federation participation);
                 bounded retry/backoff may still recover it
  ``straggler``  client delivers late by ``straggler_delay_s``
                 (simulated — compared against the round deadline)
  ``corrupt``    client's SHARED update leaves become NaN or blow up by
                 ``corrupt_scale`` (the divergent-A failure mode)
  ``feed_drop``  a train→serve publish is lost before the feed
  ``feed_stall`` a publish is held back one round (delivered late,
                 coalesced by the feed/registry as usual)
  ``pressure``   a slice of the serving ``PagePool`` is held hostage
                 for a window (admission sheds / queues instead)

Consumers: ``core.federation.run_rounds(faults=...)``,
``repro.serving.refresh.train_and_serve(faults=...)``, and
``benchmarks/serving_chaos.py``. See ``docs/robustness.md``.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded description of a fault profile. All rates in [0, 1]."""
    seed: int = 0
    # federation-side
    dropout_rate: float = 0.0        # P(client update fails this round)
    retry_success_rate: float = 0.5  # P(one bounded retry recovers it)
    straggler_rate: float = 0.0      # P(client is late this round)
    straggler_delay_s: float = 1.0   # simulated lateness of a straggler
    corrupt_rate: float = 0.0        # P(client ships a corrupted update)
    corrupt_kind: str = "nan"        # "nan" | "scale"
    corrupt_scale: float = 1e6       # blow-up factor under kind="scale"
    # train→serve bridge
    feed_drop_rate: float = 0.0      # P(a publish is lost)
    feed_stall_rounds: tuple = ()    # versions delivered one round late
    # serving-side
    page_pressure: float = 0.0       # fraction of pool pages held
    pressure_window: tuple = ()      # (start_tick, end_tick) inclusive

    def __post_init__(self):
        for f in ("dropout_rate", "retry_success_rate", "straggler_rate",
                  "corrupt_rate", "feed_drop_rate", "page_pressure"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{f}={v} outside [0, 1]")
        if self.corrupt_kind not in ("nan", "scale"):
            raise ValueError(f"corrupt_kind={self.corrupt_kind!r}")


def default_plan(seed=0):
    """The acceptance profile: 10% dropout, 5% corrupted updates, one
    feed stall (round 2) — what ``serving_chaos.py`` runs by default."""
    return FaultPlan(seed=seed, dropout_rate=0.10, corrupt_rate=0.05,
                     straggler_rate=0.10, feed_stall_rounds=(2,),
                     page_pressure=0.5)


def _key_ints(parts):
    out = []
    for p in parts:
        if isinstance(p, str):
            out.append(zlib.crc32(p.encode()))
        else:
            out.append(int(p) & 0xFFFFFFFF)
    return out


class FaultInjector:
    """Stateless-decision fault oracle + decision recorder.

    Every query hashes ``(plan.seed, kind, *key)`` into an independent
    RNG stream, so decisions are a pure function of the plan and the
    decision's identity — the property the deterministic-replay test
    (and any postmortem) rests on. ``trace``/``metrics`` are optional
    ``repro.obs`` sinks; injections emit ``fault_injected`` events and
    bump ``repro_faults_injected_total``.
    """

    def __init__(self, plan, *, trace=None, metrics=None):
        self.plan = plan
        self.trace = trace
        self.metrics = metrics
        self.decisions = []          # (kind, key, verdict) in query order

    def _uniform(self, kind, *key):
        seq = np.random.SeedSequence(
            [int(self.plan.seed) & 0xFFFFFFFF] + _key_ints((kind,) + key))
        return float(np.random.default_rng(seq).random())

    def _record(self, kind, key, verdict, **fields):
        self.decisions.append((kind, tuple(key), verdict))
        if verdict and self.trace is not None:
            self.trace.emit("fault_injected", kind=kind, **fields)
        if verdict and self.metrics is not None:
            self.metrics.counter("repro_faults_injected_total",
                                 "injected faults (all kinds)").inc()

    # -- federation-side decisions ------------------------------------------
    def client_fate(self, rnd, client, *, max_retries=1):
        """(dropped, attempts) for one client-round: the update fails
        with ``dropout_rate``; each of up to ``max_retries`` bounded
        retries recovers it with ``retry_success_rate``. ``attempts``
        counts retries actually spent (each costs one backoff step)."""
        dropped = self._uniform("dropout", rnd, client) \
            < self.plan.dropout_rate
        attempts = 0
        if dropped:
            for a in range(1, max_retries + 1):
                attempts = a
                if (self._uniform("retry", rnd, client, a)
                        < self.plan.retry_success_rate):
                    dropped = False
                    break
        self._record("dropout", (rnd, client), dropped,
                     round=rnd, client=client, retries=attempts)
        return dropped, attempts

    def straggler_delay(self, rnd, client):
        """Simulated delivery delay (seconds) of this client-round."""
        late = self._uniform("straggler", rnd, client) \
            < self.plan.straggler_rate
        self._record("straggler", (rnd, client), late,
                     round=rnd, client=client,
                     delay_s=self.plan.straggler_delay_s if late else 0.0)
        return self.plan.straggler_delay_s if late else 0.0

    def corrupts(self, rnd, client):
        """Does this client ship a corrupted (NaN/divergent) update?"""
        bad = self._uniform("corrupt", rnd, client) \
            < self.plan.corrupt_rate
        self._record("corrupt", (rnd, client), bad,
                     round=rnd, client=client,
                     corrupt_kind=self.plan.corrupt_kind)
        return bad

    def corrupt_mask(self, rnd, n_clients):
        """(C,) bool mask of corrupted clients this round."""
        return np.array([self.corrupts(rnd, c) for c in range(n_clients)])

    # -- bridge-side decisions ----------------------------------------------
    def drops_publish(self, version):
        lost = self._uniform("feed_drop", version) \
            < self.plan.feed_drop_rate
        self._record("feed_drop", (version,), lost, version=version)
        return lost

    def stalls_publish(self, version):
        stalled = version in self.plan.feed_stall_rounds
        self._record("feed_stall", (version,), stalled, version=version)
        return stalled

    # -- serving-side pressure ----------------------------------------------
    def pressure_active(self, tick):
        if not self.pressure_window_set or self.plan.page_pressure <= 0:
            return False
        lo, hi = self.plan.pressure_window
        return lo <= tick <= hi

    @property
    def pressure_window_set(self):
        return len(self.plan.pressure_window) == 2

    def count(self, kind):
        """Injected (verdict-true) decisions of one kind so far."""
        return sum(1 for k, _, v in self.decisions if k == kind and v)


class PagePressure:
    """Hold a fraction of a ``PagePool``'s free pages hostage.

    Models neighbors/leaks eating KV capacity: while applied, admission
    sees a smaller pool and must shed or queue (the ``pool_exhausted``
    path); ``release`` ends the fault window and the scheduler recovers
    on its own. Idempotent in both directions.
    """

    def __init__(self, pool, fraction):
        assert 0.0 <= fraction <= 1.0
        self.pool = pool
        self.fraction = fraction
        self.held = []

    def apply(self, injector=None):
        if self.held or self.fraction <= 0:
            return 0
        n = int(self.pool.free_count * self.fraction)
        pages = self.pool.alloc(n) if n else None
        self.held = pages or []
        if self.held and injector is not None:
            injector._record("pressure", (n,), True, pages=len(self.held))
        return len(self.held)

    def release(self):
        if self.held:
            self.pool.release(self.held)
            self.held = []
