"""Pytree checkpointing on .npz with path-flattened keys.

Federated layout (matching the paper's deployment reality): the server
checkpoint holds base params + the aggregated *shared* leaves; each client
checkpoint holds only that client's *local* leaves. ``save_federated`` /
``load_federated`` split/merge along ``core.strategies`` roles.

All writes are atomic: bytes land in a same-directory temp file that is
``os.replace``d over the target only after a flush+fsync, so a crash
mid-save can never leave a torn checkpoint — the old file either
survives intact or the new one is complete.
"""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import LOCAL, leaf_role

_SEP = "||"


def _atomic_savez(path, arrays):
    """Write ``np.savez(path, **arrays)`` atomically.

    The temp file lives in the target's directory (os.replace must not
    cross filesystems) and is passed to ``np.savez`` as an open handle —
    numpy appends ``.npz`` to *names* but never to file objects, so the
    rename source is exactly what was written. On any failure the temp
    file is removed and the previous checkpoint (if any) is untouched.
    """
    path = os.path.abspath(path)
    if not path.endswith(".npz"):      # match np.savez(str_path) naming
        path += ".npz"
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                               prefix=os.path.basename(path) + ".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(path, tree):
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    _atomic_savez(path, _flatten(tree))


def load_pytree(path, like):
    """Restore into the structure of ``like`` (dtypes preserved from disk)."""
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = _SEP.join(str(getattr(pp, "key", getattr(pp, "idx", pp)))
                        for pp in p)
        arr = jnp.asarray(data[key])
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


def save_federated(dirpath, client_adapters, mode, server_extra=None):
    """Server file: shared+frozen leaves of client 0 (identical across
    clients after aggregation). Client files: local leaves only."""
    os.makedirs(dirpath, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(client_adapters)[0]
    server, locals_ = {}, {}
    n_clients = None
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        n_clients = leaf.shape[0]
        if leaf_role(path, mode) == LOCAL:
            locals_[key] = np.asarray(leaf)
        else:
            server[key] = np.asarray(leaf[0])
    if server_extra:
        for k, v in _flatten(server_extra).items():
            server["extra" + _SEP + k] = v
    _atomic_savez(os.path.join(dirpath, "server.npz"), server)
    for c in range(n_clients):
        _atomic_savez(os.path.join(dirpath, f"client_{c}.npz"),
                      {k: v[c] for k, v in locals_.items()})


def load_federated(dirpath, like, mode):
    """Inverse of save_federated into the structure of ``like``."""
    server = np.load(os.path.join(dirpath, "server.npz"))
    flat, _ = jax.tree_util.tree_flatten_with_path(like)
    n_clients = flat[0][1].shape[0]
    client_files = [np.load(os.path.join(dirpath, f"client_{c}.npz"))
                    for c in range(n_clients)]
    leaves = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if leaf_role(path, mode) == LOCAL:
            arr = jnp.stack([jnp.asarray(cf[key]) for cf in client_files])
        else:
            arr = jnp.broadcast_to(jnp.asarray(server[key])[None],
                                   leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
