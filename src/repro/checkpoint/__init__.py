from repro.checkpoint.npz import (load_pytree, save_pytree,
                                  load_federated, save_federated)

__all__ = ["load_pytree", "save_pytree", "load_federated", "save_federated"]
