"""Serving launcher: compile prefill/serve_step for the production mesh,
or run a real batched decode on the host mesh.

  python -m repro.launch.serve --arch qwen3-32b --shape decode_32k [--multi-pod]
  python -m repro.launch.serve --arch qwen3-32b --execute
  python -m repro.launch.serve --arch deepseek-7b --multi-tenant [--clients 8]
  python -m repro.launch.serve --arch deepseek-7b --multi-tenant \
      --fleet mixed --lora-backend sgmv
  python -m repro.launch.serve --arch deepseek-7b --multi-tenant \
      --decode-backend fused --decode-ticks 8
  python -m repro.launch.serve --arch deepseek-7b --live-refresh \
      [--train-rounds 4]

Any serving run takes ``--metrics-out`` (Prometheus text exposition or
JSON snapshot of the engine's repro.obs registry, by extension) and
``--trace-out`` (JSONL structured event timeline) — see
docs/observability.md.
"""
import os

if __name__ == "__main__" and os.environ.get("XLA_FLAGS") is None:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import dataclasses  # noqa: E402


def _make_sinks(args):
    """(metrics, trace) for a serving run: the registry always exists
    (report() percentiles ride it); the trace only when requested."""
    from repro.obs import MetricsRegistry, TraceLog
    metrics = MetricsRegistry()
    trace = TraceLog() if args.trace_out else None
    return metrics, trace


def _write_sinks(args, metrics, trace):
    from repro.obs import write_metrics
    if args.metrics_out:
        write_metrics(args.metrics_out, metrics)
        print(f"metrics → {args.metrics_out}")
    if args.trace_out and trace is not None:
        trace.save(args.trace_out)
        print(f"trace ({len(trace.events)} events) → {args.trace_out}")


def run_multi_tenant(args, acfg):
    """Serve a mixed-client request stream through repro.serving.

    ``--fleet`` picks the tenant population: ``fedsa`` (shared Ā,
    per-client B_i — the paper's invariant, bgmv-legal), ``fedit``
    (every client owns its whole adapter pair — per-client A tables,
    the SGMV path), or ``mixed`` (half FedSA, half FedIT tenants in ONE
    registry and ONE grouped batch).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.core.adapters import init_adapters
    from repro.models.transformer import init_model
    from repro.serving import AdapterRegistry, ServingConfig, ServingEngine
    from repro.serving.demo import mixed_fleet, synthetic_clients

    cfg = reduced(get_config(args.arch))
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg, jnp.float32)
    if args.fleet == "feddpa" and acfg.mode != "feddpa":
        # the dual-adapter fleet needs the doubled global/personal leaf
        # structure from init_adapters
        acfg = dataclasses.replace(acfg, mode="feddpa")
    # stand-in for a trained FedSystem: shared Ā, client-specific B_i
    # (and client-specific A_i under the fedit / mixed fleets)
    template = {"adapters": init_adapters(key, cfg, acfg)}
    fleet = args.fleet
    if fleet == "mixed":
        trees, modes = mixed_fleet(template, args.clients, seed=7)
        reg_mode = "fedit"      # A+B tables cover both tenant kinds
    else:
        reg_mode = fleet if fleet != "fedsa" else acfg.mode
        trees = synthetic_clients(template, args.clients, mode=reg_mode,
                                  seed=7)
        modes = [reg_mode] * args.clients
    reg = AdapterRegistry(template, n_slots=args.slots, mode=reg_mode)
    for i, tree in enumerate(trees):
        reg.ingest(i, tree)
    metrics, trace = _make_sinks(args)
    # ONE place argparse flags meet engine knobs: the config builder
    scfg = ServingConfig.from_args(args, max_batch=min(8, args.clients),
                                   max_seq=64)
    engine = ServingEngine(cfg, params, acfg, reg, scfg,
                           metrics=metrics, trace=trace)
    rng = np.random.default_rng(0)
    if scfg.prefix_cache:
        # shared-prefix traffic: every client front-loads the same
        # system prompt, suffixes diverge — the shape the cache serves
        head = rng.integers(0, cfg.vocab_size, 2 * scfg.page_size)
        for r in range(args.requests):
            tail = rng.integers(0, cfg.vocab_size, int(rng.integers(4, 13)))
            engine.submit(r % args.clients, np.concatenate([head, tail]),
                          max_new_tokens=16)
    else:
        for r in range(args.requests):
            plen = int(rng.integers(4, 33))      # heterogeneous prompts
            engine.submit(r % args.clients,
                          rng.integers(0, cfg.vocab_size, plen),
                          max_new_tokens=16)
    rep = engine.run()
    if rep["sharded"]:
        d, m = rep["mesh_shape"]
        print(f"sharded over a {d}x{m} mesh ({d*m} devices: {d}-way rows, "
              f"{m}-way model), {rep['collective_flips']} collective "
              f"flips, {rep['cross_shard_allocs'] or 0} cross-shard page "
              "allocs")
    extra = (f", page util {rep['page_utilization']:.2f}"
             if rep["kv_layout"] == "paged" else "")
    fleet_note = (f"{fleet} fleet "
                  f"({modes.count('fedsa')} fedsa + "
                  f"{modes.count('fedit')} fedit)" if fleet == "mixed"
                  else f"{fleet} fleet")
    print(f"served {rep['requests']} requests from {args.clients} clients "
          f"[{fleet_note}] ({args.slots} adapter slots, "
          f"{rep['kv_layout']} kv, {rep['lora_backend']} lora): "
          f"{rep['tokens']} tokens in {rep['wall_s']:.1f}s = "
          f"{rep['tok_per_s']:.1f} tok/s "
          f"({rep['decode_tok_per_s']:.1f} decode-only), "
          f"occupancy {rep['batch_occupancy']:.2f}, "
          f"adapter hit rate {rep['adapter_hit_rate']:.2f}{extra}")
    if rep["tier_host_hits"] or rep["tier_cold_misses"] \
            or rep["prefetches"]:
        hr = rep["host_hit_rate"]
        rate = f"{hr:.2f}" if hr is not None else "n/a"
        print(f"tiering: {rep['tier_host_hits']} host-hits, "
              f"{rep['tier_cold_misses']} cold misses "
              f"(host hit rate {rate}), {rep['prefetches']} prefetches, "
              f"{rep['tier_promotions']} promotions, "
              f"{rep['tier_demotions']} demotions, "
              f"occupancy {rep['tier_occupancy']}")
    if scfg.prefix_cache:
        hr = rep["prefix_hit_rate"]
        rate = f"{hr:.2f}" if hr is not None else "n/a"
        print(f"prefix cache: {rep['prefix_hits']} hits (rate {rate}), "
              f"{rep['prefix_hit_tokens']} tokens reused, "
              f"{rep['pages_shared']} pages shared, "
              f"{rep['cow_copies']} CoW copies, "
              f"{rep['prefix_evictions']} evictions, "
              f"{rep['prefix_entries']} entries resident")
    if rep["shed_requests"] or rep["degraded_served"] \
            or rep["deadline_retired"]:
        print(f"degradation: {rep['shed_requests']} shed, "
              f"{rep['degraded_served']} degraded, "
              f"{rep['deadline_retired']} deadline-retired")
    if rep["ttft_p50_s"] is not None:
        print(f"latency: ttft p50 {rep['ttft_p50_s']*1e3:.1f}ms / "
              f"p99 {rep['ttft_p99_s']*1e3:.1f}ms, e2e p50 "
              f"{rep['e2e_p50_s']*1e3:.1f}ms / p99 "
              f"{rep['e2e_p99_s']*1e3:.1f}ms, intertoken p50 "
              f"{rep['intertoken_p50_s']*1e6:.0f}us")
    _write_sinks(args, metrics, trace)


def run_live_refresh(args, acfg):
    """Background federation publishing into a foreground engine — the
    repro.serving.refresh bridge, end to end on the host backend."""
    from repro.configs import FedConfig, get_config, reduced
    from repro.serving import ServingConfig, train_and_serve

    cfg = reduced(get_config(args.arch), n_layers=2, d_model=64)
    fed = FedConfig(n_clients=args.clients, local_steps=2)
    metrics, trace = _make_sinks(args)
    faults = robust = None
    if args.chaos_seed is not None:
        from repro.core.federation import RobustConfig
        from repro.failures import FaultInjector, default_plan
        faults = FaultInjector(default_plan(args.chaos_seed),
                               trace=trace, metrics=metrics)
        robust = RobustConfig()
    scfg = ServingConfig.from_args(args, max_batch=4, max_seq=32)
    report, history = train_and_serve(
        cfg, acfg, fed, rounds=args.train_rounds, n_slots=args.slots,
        requests=args.requests, log=print, metrics=metrics, trace=trace,
        config=scfg, faults=faults, robust=robust)
    if faults is not None:
        print(f"chaos (seed {args.chaos_seed}): "
              f"{faults.count('dropout')} dropouts, "
              f"{faults.count('corrupt')} corrupted updates, "
              f"{faults.count('feed_drop')} publish drops, "
              f"{faults.count('feed_stall')} stalls; "
              f"{sum(len(r) for r in history.get('rejected', []))} "
              f"rejected, "
              f"{history.get('rollbacks', 0)} rollbacks, "
              f"{report['shed_requests']} shed, "
              f"{report['degraded_served']} degraded")
    print(f"final train loss {history['loss'][-1]:.4f}; engine at "
          f"adapter version {report['adapter_version']}, "
          f"{report['decode_tok_per_s']:.1f} decode tok/s")
    _write_sinks(args, metrics, trace)


def main():
    import jax
    import jax.numpy as jnp

    from repro.configs import AdapterConfig, get_config, get_shape, reduced
    from repro.launch.entry import build_entry, lower_entry, skip_reason
    from repro.launch.mesh import make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k",
                    choices=["prefill_32k", "decode_32k", "long_500k"])
    ap.add_argument("--mode", default="fedsa")
    ap.add_argument("--variant", default="lora")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--execute", action="store_true")
    ap.add_argument("--multi-tenant", action="store_true",
                    help="run the repro.serving engine: mixed-client "
                         "batched decode on the host backend")
    ap.add_argument("--live-refresh", action="store_true",
                    help="train federated rounds in the background and "
                         "absorb each round's adapters into a running "
                         "engine (repro.serving.refresh)")
    ap.add_argument("--train-rounds", type=int, default=4)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--kv-layout", default="auto",
                    choices=["auto", "paged", "dense"])
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=None,
                    help="KV pool size in pages (paged layout only; "
                         "default: worst case for max_batch × max_seq). "
                         "Undersize it to exercise prefix-cache "
                         "eviction / admission backpressure")
    ap.add_argument("--attn-backend", default="xla",
                    choices=["xla", "pallas"])
    ap.add_argument("--lora-backend", default="jnp",
                    choices=["jnp", "bgmv", "sgmv"])
    ap.add_argument("--decode-backend", default="per-tick",
                    choices=["per-tick", "fused"],
                    help="fused runs up to --decode-ticks decode ticks "
                         "inside one jitted scan (host syncs only at "
                         "scan boundaries)")
    ap.add_argument("--decode-ticks", type=int, default=8,
                    help="max ticks per fused decode scan")
    ap.add_argument("--metrics-out", default=None,
                    help="write the run's metrics registry here: "
                         ".prom/.txt → Prometheus text exposition, "
                         "anything else → JSON snapshot")
    ap.add_argument("--trace-out", default=None,
                    help="write the structured event timeline (JSONL, "
                         "one event per line) here")
    ap.add_argument("--host-ring-slots", type=int, default=None,
                    help="bound the pinned-host-RAM adapter ring (the "
                         "tier under the HBM slot tables); overflow "
                         "demotes to the cold store (default: unbounded "
                         "host tier, no cold traffic)")
    ap.add_argument("--cold-dir", default=None,
                    help="cold adapter store directory (atomic npz per "
                         "client); default: in-memory cold tier")
    ap.add_argument("--prefetch-lookahead", type=int, default=0,
                    help="queued admits whose adapters are promoted "
                         "host-ward in the background each tick "
                         "(0 = no prefetch)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the admission queue: a submit past it "
                         "is shed (request_shed) instead of growing "
                         "host memory (default: unbounded)")
    ap.add_argument("--request-deadline", type=float, default=None,
                    help="per-request submit→retire budget in seconds; "
                         "overdue rows retire cleanly with "
                         "deadline_exceeded (default: none)")
    ap.add_argument("--degrade-after", type=float, default=None,
                    help="serve the base model (degraded) when a "
                         "request can't acquire an adapter slot within "
                         "this many seconds (default: disabled)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="--multi-tenant paged runs: cache page-aligned "
                         "prompt-prefix KV per adapter version and serve "
                         "repeats by pointing new rows at the cached "
                         "pages (copy-on-write; repro.serving.prefix). "
                         "The launcher workload switches to shared-"
                         "prefix prompts so the cache has something "
                         "to hit")
    ap.add_argument("--prefix-chunk-pages", type=int, default=1,
                    help="pages per cached prefix chunk (>= 1)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="--live-refresh only: drive the run through "
                         "repro.failures.default_plan(seed) — client "
                         "dropout, corrupted updates, feed stalls — "
                         "with the robust federation path on")
    ap.add_argument("--shard-serving", action="store_true",
                    help="partition the serving engine over a (data, "
                         "model) device mesh: base weights tensor-"
                         "parallel, KV pool + decode rows batch-sharded, "
                         "refresh flips verified by a mesh-wide "
                         "collective (repro.serving.sharded)")
    ap.add_argument("--mesh-shape", default=None,
                    help="serving mesh extents as DATAxMODEL, e.g. 4x1 "
                         "or 2x2 (default: all visible devices on the "
                         "data axis); requires --shard-serving")
    ap.add_argument("--fleet", default="fedsa",
                    choices=["fedsa", "fedit", "feddpa", "mixed"],
                    help="tenant population for --multi-tenant: fedsa "
                         "(shared Ā, per-client B), fedit (per-client A "
                         "AND B — the SGMV path), feddpa (dual adapters, "
                         "personal pair per client), or mixed (half "
                         "fedsa + half fedit in one grouped batch)")
    args = ap.parse_args()

    acfg = AdapterConfig(mode=args.mode, variant=args.variant)
    if args.live_refresh:
        return run_live_refresh(args, acfg)
    if args.multi_tenant:
        return run_multi_tenant(args, acfg)
    if args.execute:
        from repro.core.adapters import init_adapters
        from repro.models.transformer import (decode_step, init_model,
                                              prefill)
        cfg = reduced(get_config(args.arch))
        key = jax.random.PRNGKey(0)
        params = init_model(key, cfg, jnp.float32)
        adapters = init_adapters(key, cfg, acfg)
        B, L, Smax = 2, 8, 24
        toks = jax.random.randint(key, (B, L), 0, cfg.vocab_size)
        frames = (jnp.zeros((B, cfg.enc_seq, cfg.d_model), jnp.float32)
                  if cfg.enc_dec else None)
        logits, cache, _ = prefill(cfg, params, adapters, acfg, toks, Smax,
                                   enc_frames=frames)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        out = [tok]
        for i in range(8):
            pos = jnp.full((B,), L + i, jnp.int32)
            logits, cache = decode_step(cfg, params, adapters, acfg, tok,
                                        pos, cache)
            tok = jnp.argmax(logits[:, 0], -1)[:, None]
            out.append(tok)
        print("generated:", jnp.concatenate(out, 1).tolist())
        return

    cfg = get_config(args.arch)
    shape = get_shape(args.shape)
    if skip_reason(cfg, shape):
        print(f"SKIP: {skip_reason(cfg, shape)}")
        return
    from repro.obs import Timer
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    entry = build_entry(cfg, shape, mesh, acfg)
    with Timer() as t:
        compiled = lower_entry(entry, mesh).compile()
    print(f"compiled {entry.name} ({entry.note or 'native'}) for "
          f"{mesh.devices.shape} in {t.elapsed:.1f}s")
    mem = compiled.memory_analysis()
    print(f"per-device: args {mem.argument_size_in_bytes/2**30:.2f} GiB, "
          f"temp {mem.temp_size_in_bytes/2**30:.2f} GiB")


if __name__ == "__main__":
    main()
