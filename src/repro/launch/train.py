"""Training launcher: run (or just compile) the in-mesh federated round.

On this CPU container the production meshes exist only as placeholder
devices, so `--execute` is limited to the host mesh with a reduced config;
the default mode lowers+compiles the full config for the production mesh
and prints the memory/cost summary (the dry-run contract).

  python -m repro.launch.train --arch deepseek-7b [--multi-pod]
      [--mode fedsa] [--variant lora] [--local-steps 1] [--microbatches 4]
  python -m repro.launch.train --arch deepseek-7b --execute   # host mesh
"""
import os

if __name__ == "__main__" and os.environ.get("XLA_FLAGS") is None:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import time  # noqa: E402


def main():
    import jax
    import jax.numpy as jnp

    from repro.configs import AdapterConfig, get_config, get_shape, reduced
    from repro.configs.base import InputShape
    from repro.launch.entry import build_entry, lower_entry
    from repro.launch.mesh import make_host_mesh, make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mode", default="fedsa",
                    choices=["fedavg", "ffa", "fedsa", "feddpa"])
    ap.add_argument("--variant", default="lora",
                    choices=["lora", "rslora", "vera"])
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--execute", action="store_true",
                    help="run a real round on the 1×1 host mesh (reduced cfg)")
    args = ap.parse_args()

    acfg = AdapterConfig(mode=args.mode, variant=args.variant)
    if args.execute:
        cfg = reduced(get_config(args.arch))
        mesh = make_host_mesh()
        shape = InputShape("host_train", seq_len=64, global_batch=2,
                           kind="train")
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shape = get_shape(args.shape)

    entry = build_entry(cfg, shape, mesh, acfg,
                        local_steps=args.local_steps,
                        microbatches=args.microbatches)
    t0 = time.time()
    compiled = lower_entry(entry, mesh).compile()
    print(f"compiled {entry.name} for {mesh.devices.shape} "
          f"in {time.time()-t0:.1f}s")
    mem = compiled.memory_analysis()
    print(f"per-device: args {mem.argument_size_in_bytes/2**30:.2f} GiB, "
          f"temp {mem.temp_size_in_bytes/2**30:.2f} GiB")
    if args.execute:
        zeros = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), entry.args)
        adapters, opt_state, loss = compiled(*zeros)
        print(f"executed one federated round: loss={float(loss):.4f}")


if __name__ == "__main__":
    main()
