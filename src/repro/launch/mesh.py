"""Production mesh definitions.

Functions, not module-level constants — importing this module never touches
jax device state. The dry-run sets ``XLA_FLAGS=--xla_force_host_platform_
device_count=512`` *before* importing jax so both meshes can be built on a
CPU host.

single-pod : (16, 16)        axes ("data", "model")   — 256 chips (v5e pod)
multi-pod  : (2, 16, 16)     axes ("pod", "data", "model") — 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh needs {n} devices, found {len(devices)}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_host_mesh():
    """1×1 mesh over the single real device (tests / examples)."""
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
