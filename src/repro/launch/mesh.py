"""Mesh construction: one general factory + the production presets.

Functions, not module-level constants — importing this module never touches
jax device state. The dry-run sets ``XLA_FLAGS=--xla_force_host_platform_
device_count=512`` *before* importing jax so both production meshes can be
built on a CPU host; tests and the sharded serving engine build small
meshes (e.g. (4, 1), (2, 2)) through ``make_mesh`` under the same flag.

single-pod : (16, 16)        axes ("data", "model")   — 256 chips (v5e pod)
multi-pod  : (2, 16, 16)     axes ("pod", "data", "model") — 512 chips
"""
from __future__ import annotations

import jax

_DEFAULT_AXES = {2: ("data", "model"), 3: ("pod", "data", "model")}


def make_mesh(shape, axes=None):
    """A ``jax.sharding.Mesh`` of the given shape over the first
    ``prod(shape)`` devices.

    ``axes`` defaults to ``("data", "model")`` for 2-d shapes and
    ``("pod", "data", "model")`` for 3-d ones — the axis names every
    spec builder in ``repro.sharding.rules`` keys on. Raises with the
    ``XLA_FLAGS`` hint when the host exposes too few devices (CPU hosts
    fake a device count with
    ``--xla_force_host_platform_device_count=N``).
    """
    shape = tuple(int(s) for s in shape)
    if not shape or any(s < 1 for s in shape):
        raise ValueError(f"mesh shape {shape}: need positive extents")
    if axes is None:
        if len(shape) not in _DEFAULT_AXES:
            raise ValueError(f"no default axis names for a {len(shape)}-d "
                             "mesh; pass axes=")
        axes = _DEFAULT_AXES[len(shape)]
    axes = tuple(axes)
    if len(axes) != len(shape):
        raise ValueError(f"mesh shape {shape} vs axes {axes}: rank mismatch")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)}; run "
            f"under XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            "(set BEFORE jax is imported)")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    return make_mesh(shape)


def make_host_mesh():
    """1×1 mesh over the single real device (tests / examples)."""
    return make_mesh((1, 1))
