"""Trip-count-weighted analysis of optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE — for
layer-scanned models that under-reports FLOPs/bytes by ~n_layers×. The
optimized HLO annotates ``backend_config={"known_trip_count":{"n": ...}}``
on every while, so this module re-derives per-device costs with proper
loop weighting:

  * flops       — MXU work: 2·M·N·K per dot (incl. dots inside fusions),
                  weighted by enclosing trip counts. Elementwise VPU FLOPs
                  are excluded (they are bandwidth-bound; see bytes).
  * bytes       — Σ over surface ops of (operand + result) sizes — the
                  standard bytes-accessed metric at fusion boundaries.
  * collectives — result bytes of all-reduce / all-gather / reduce-scatter /
                  all-to-all / collective-permute, trip-weighted, by kind.

All numbers are per-device (the compiled module IS the per-device program).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# tuple result types contain "/*index=N*/" comments — allow anything but
# parens inside the tuple (HLO types never nest parens)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_CALL_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVE_OPS = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute", "all-reduce-start",
                  "all-gather-start", "collective-permute-start",
                  "ragged-all-to-all"}


def _type_numel_bytes(type_str):
    total_b = 0
    total_n = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_n += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_n, total_b


class Op:
    __slots__ = ("name", "type", "opcode", "line")

    def __init__(self, name, type_, opcode, line):
        self.name = name
        self.type = type_
        self.opcode = opcode
        self.line = line


def parse_module(text):
    """HLO text → {computation_name: [Op, ...]}, entry_name."""
    comps = {}
    entry = None
    cur = None
    for line in text.splitlines():
        s = line.rstrip()
        mc = _COMP_RE.match(s)
        if mc and (s.endswith("{")):
            cur = mc.group(1)
            comps[cur] = []
            if s.startswith("ENTRY"):
                entry = cur
            continue
        if s.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(s)
        if mo:
            comps[cur].append(Op(mo.group(1), mo.group(2), mo.group(3), s))
    return comps, entry


def _dot_flops(op, types):
    """2 × numel(result) × K. K = product of lhs contracting dim sizes."""
    res_n, _ = _type_numel_bytes(op.type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    operands = _operands(op)
    if not m or not operands:
        return 2 * res_n  # degenerate
    lhs_type = types.get(operands[0])
    if lhs_type is None:
        return 2 * res_n
    dims_m = _SHAPE_RE.search(lhs_type)
    if not dims_m:
        return 2 * res_n
    lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
    k = 1
    for idx in m.group(1).split(","):
        if idx:
            i = int(idx)
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    return 2 * res_n * k


def _operands(op):
    """Operand names: %refs inside the call parens (before attributes)."""
    i = op.line.find(op.opcode + "(")
    seg = op.line[i + len(op.opcode) + 1:]
    # cut at the matching close paren — approximate: stop at '), '
    depth = 1
    buf = []
    for ch in seg:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    return _OPERAND_RE.findall("".join(buf))


def analyze(text):
    """→ dict(flops=, bytes=, collective_bytes=, collectives={kind: bytes},
    per device, trip-count weighted)."""
    comps, entry = parse_module(text)
    memo = {}

    def comp_cost(name):
        if name in memo:
            return memo[name]
        flops = 0.0
        bytes_ = 0.0
        coll = defaultdict(float)
        types = {}
        for op in comps.get(name, ()):
            types[op.name] = op.type
        for op in comps.get(name, ()):
            oc = op.opcode
            if oc == "while":
                m = _TRIP_RE.search(op.line)
                trips = int(m.group(1)) if m else 1
                called = _CALL_RE.findall(op.line)
                # body=..., condition=... — weight both by trip count
                for c in called:
                    f, b, cl = comp_cost(c)
                    flops += trips * f
                    bytes_ += trips * b
                    for k, v in cl.items():
                        coll[k] += trips * v
                continue
            if oc in ("fusion", "call", "custom-call", "map", "reduce",
                      "reduce-window", "scatter", "sort", "select-and-scatter"):
                for c in _CALL_RE.findall(op.line):
                    f, b, cl = comp_cost(c)
                    flops += f
                    # inner bytes of a fusion are on-chip; count boundary only
                    for k, v in cl.items():
                        coll[k] += v
                _, rb = _type_numel_bytes(op.type)
                ob = 0
                for o in _operands(op):
                    if o in types:
                        ob += _type_numel_bytes(types[o])[1]
                bytes_ += rb + ob
                continue
            if oc == "conditional":
                br = _COND_BRANCHES_RE.search(op.line)
                names = ([x.strip().lstrip("%") for x in
                          br.group(1).split(",")] if br
                         else _CALL_RE.findall(op.line))
                if names:
                    costs = [comp_cost(c) for c in names]
                    fmax = max(c[0] for c in costs)
                    bmax = max(c[1] for c in costs)
                    flops += fmax
                    bytes_ += bmax
                    for c in costs:
                        for k, v in c[2].items():
                            coll[k] += v / len(costs)
                continue
            if oc in COLLECTIVE_OPS:
                kind = oc.replace("-start", "")
                _, rb = _type_numel_bytes(op.type)
                coll[kind] += rb
                bytes_ += rb
                continue
            if oc in ("dot", "convolution"):
                flops += _dot_flops(op, types)
                _, rb = _type_numel_bytes(op.type)
                ob = sum(_type_numel_bytes(types[o])[1]
                         for o in _operands(op) if o in types)
                bytes_ += rb + ob
                continue
            if oc in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "after-all", "all-reduce-done",
                      "all-gather-done", "collective-permute-done"):
                continue
            # plain surface op (copy, broadcast, slice, dus, gather, ...)
            _, rb = _type_numel_bytes(op.type)
            ob = sum(_type_numel_bytes(types[o])[1]
                     for o in _operands(op) if o in types)
            bytes_ += rb + ob
        memo[name] = (flops, bytes_, dict(coll))
        return memo[name]

    f, b, cl = comp_cost(entry)
    return {"flops": f, "bytes": b,
            "collective_bytes": sum(cl.values()),
            "collectives": {k: v for k, v in sorted(cl.items())}}
