# The dry-run (and ONLY the dry-run) needs 512 placeholder devices. This
# must happen before ANY other import — jax locks device count on first init.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch × shape × mesh) lowers and compiles.

For each combination this lowers the shape's entry point (fed_train_step /
prefill_step / serve_step) with ShapeDtypeStruct inputs (no allocation),
compiles it for the production mesh, and records:

  * memory_analysis()  — per-device bytes (proves it fits)
  * cost_analysis()    — per-device FLOPs / bytes for §Roofline
  * collective bytes   — parsed from the optimized HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute)

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json, which
benchmarks/roofline.py turns into the §Roofline table.

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import sys
import traceback

import jax  # noqa: F401  — locks the device count with XLA_FLAGS set above

from repro.configs import ASSIGNED, SHAPES, AdapterConfig, get_config, get_shape
from repro.launch.entry import build_entry, lower_entry, skip_reason
from repro.launch.mesh import make_production_mesh
from repro.obs import Timer

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _tensor_bytes(type_str):
    """Bytes of one HLO result type, e.g. 'f32[8,128]' or a tuple thereof."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text):
    """Sum result-operand sizes of every collective op (per device),
    bucketed by collective kind."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.split(" = ", 1)
        if len(eq) != 2:
            continue
        rhs = eq[1]
        for kind in _COLLECTIVES:
            # match 'f32[..] all-reduce(' and async '...-start(' forms,
            # skipping '-done' (would double count)
            if f" {kind}(" in rhs or f" {kind}-start(" in rhs:
                ty = rhs.split(" ", 1)[0]
                out[kind]["count"] += 1
                out[kind]["bytes"] += _tensor_bytes(ty)
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def run_one(arch, shape_name, multi_pod=False, acfg=None, outdir=None,
            entry_kw=None):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "ok"}
    reason = skip_reason(cfg, shape)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    with Timer() as t_lower:
        entry = build_entry(cfg, shape, mesh, acfg or AdapterConfig(),
                            **(entry_kw or {}))
        rec["note"] = entry.note
        lowered = lower_entry(entry, mesh)
    rec["lower_s"] = round(t_lower.elapsed, 1)
    with Timer() as t_compile:
        compiled = lowered.compile()
    rec["compile_s"] = round(t_compile.elapsed, 1)

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # noqa: BLE001 — CPU backend may not support it
        rec["memory"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and (
                           "flops" in k or "bytes" in k or k == "utilization")}
    except Exception as e:  # noqa: BLE001
        rec["cost"] = {"error": str(e)}
    hlo = compiled.as_text()
    rec["collectives"] = collective_stats(hlo)   # unweighted (legacy)
    # trip-count-weighted per-device FLOPs/bytes/collectives — the roofline
    # source (cost_analysis counts while bodies once; see hlo_analysis.py)
    from repro.launch.hlo_analysis import analyze
    try:
        rec["hlo"] = analyze(hlo)
    except Exception as e:  # noqa: BLE001
        rec["hlo"] = {"error": str(e)}
    rec["n_devices"] = mesh.devices.size
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ASSIGNED), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--variant", default="lora",
                    choices=["lora", "rslora", "vera"])
    ap.add_argument("--mode", default="fedsa",
                    choices=["fedavg", "ffa", "fedsa", "feddpa"])
    args = ap.parse_args()

    acfg = AdapterConfig(variant=args.variant, mode=args.mode)
    pairs = []
    if args.all:
        for a in sorted(ASSIGNED):
            for s in SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    os.makedirs(args.outdir, exist_ok=True)
    failures = 0
    for arch, shape in pairs:
        tag = f"{arch}__{shape}__" + ("pod2x16x16" if args.multi_pod
                                      else "pod16x16")
        try:
            rec = run_one(arch, shape, multi_pod=args.multi_pod, acfg=acfg)
        except Exception:  # noqa: BLE001
            rec = {"arch": arch, "shape": shape, "status": "FAILED",
                   "traceback": traceback.format_exc()}
            failures += 1
        with open(os.path.join(args.outdir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f"lower {rec['lower_s']}s compile {rec['compile_s']}s "
                     f"coll {rec['collectives']['total_bytes']/2**20:.1f} MiB")
        print(f"[dryrun] {tag}: {status} {extra}", flush=True)
        if status == "ok":
            mem = rec.get("memory", {})
            if "temp_size_in_bytes" in mem:
                print(f"  memory: args {mem.get('argument_size_in_bytes',0)/2**30:.2f} GiB "
                      f"out {mem.get('output_size_in_bytes',0)/2**30:.2f} GiB "
                      f"temp {mem.get('temp_size_in_bytes',0)/2**30:.2f} GiB",
                      flush=True)
            cost = rec.get("cost", {})
            if "flops" in cost:
                print(f"  cost: {cost['flops']/1e9:.1f} GFLOP/device, "
                      f"bytes {cost.get('bytes accessed', 0)/2**30:.2f} GiB",
                      flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
