"""Lowered entry points per input shape + their ShapeDtypeStruct inputs.

One builder per shape kind:

  train_4k     → ``fed_train_step``  (E local steps + selective aggregation)
  prefill_32k  → ``prefill_step``    (prompt → cache + last-token logits)
  decode_32k   → ``serve_step``      (1 token against a seq_len cache)
  long_500k    → ``serve_step``      (sub-quadratic archs; dense archs run a
                                      sliding-window variant; skips recorded)

Each builder returns an ``Entry``: the function, its abstract args
(ShapeDtypeStructs — nothing is allocated), and in/out sharding spec trees.
``launch.dryrun`` lowers/compiles them on the production meshes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import AdapterConfig
from repro.core.adapters import init_adapters
from repro.core.aggregation import aggregate, broadcast_clients
from repro.core.strategies import trainable_mask
from repro.models.transformer import (decode_step, init_cache, init_model,
                                      loss_fn, prefill)
from repro.optim import apply_updates, sgd
from repro.sharding.rules import (adapter_specs, batch_specs, cache_specs,
                                  dp_axis, param_specs)

SLIDING_WINDOW = 16_384


@dataclasses.dataclass
class Entry:
    name: str
    fn: Any
    args: Tuple[Any, ...]
    in_specs: Tuple[Any, ...]
    out_specs: Any
    donate_argnums: Tuple[int, ...] = ()
    note: str = ""


def _dp_size(mesh):
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names
                        if a != "model"]))


def shape_dtype(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def abstract_model(cfg, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_model, cfg=cfg, dtype=dtype),
        jax.random.PRNGKey(0))


def abstract_adapters(cfg, acfg, n_clients=None):
    ad = jax.eval_shape(
        functools.partial(init_adapters, cfg=cfg, acfg=acfg),
        jax.random.PRNGKey(0))
    if n_clients is not None:
        ad = jax.eval_shape(
            functools.partial(broadcast_clients, n_clients=n_clients), ad)
    return ad


def skip_reason(cfg, shape) -> Optional[str]:
    """Non-None → this (arch, shape) pair is skipped (recorded in DESIGN)."""
    if shape.name == "long_500k" and cfg.enc_dec:
        return ("encoder-decoder with ~1.5k-frame encoder; 524288-token "
                "decode is architecturally meaningless")
    return None


def variant_for_shape(cfg, shape):
    """long_500k on full-attention archs → sliding-window variant."""
    note = ""
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm") \
            and cfg.mla is None and cfg.sliding_window is None:
        cfg = dataclasses.replace(cfg, sliding_window=SLIDING_WINDOW)
        note = f"sliding-window {SLIDING_WINDOW} variant"
    return cfg, note


# ---------------------------------------------------------------------------
# train_4k — the paper's round as ONE lowered program
# ---------------------------------------------------------------------------

def make_fed_train_step(cfg, acfg, lr=1e-2, momentum=0.9, local_steps=1,
                        microbatches=1):
    """In-mesh federated round: clients = dp groups.

    adapters/opt_state carry a leading client axis sharded over dp; the
    selective aggregation mean lowers to an all-reduce over dp of the
    SHARED leaves only (FedSA: the A matrices — half of FedAvg's bytes).

    ``microbatches`` > 1 splits each local batch into grad-accumulation
    chunks (§Perf it. 3b): activation memory scales 1/m at the cost of
    re-streaming the frozen weights m× (compute/semantics unchanged).
    """
    opt_init, opt_update = sgd(lr, momentum)

    def fed_train_step(params, adapters, opt_state, batch):
        mask = trainable_mask(shape_dtype_like_first_client(adapters),
                              acfg.mode)

        def grads_of(ad, b):
            if microbatches == 1:
                return jax.value_and_grad(
                    lambda a: loss_fn(cfg, params, a, acfg, b, remat=True)
                )(ad)
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), b)

            def acc(carry, bi):
                lsum, gsum = carry
                l, g = jax.value_and_grad(
                    lambda a: loss_fn(cfg, params, a, acfg, bi, remat=True)
                )(ad)
                return (lsum + l,
                        jax.tree_util.tree_map(jnp.add, gsum, g)), None

            zeros = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), ad)
            (lsum, gsum), _ = jax.lax.scan(acc, (0.0, zeros), mb)
            scale = 1.0 / microbatches
            return lsum * scale, jax.tree_util.tree_map(
                lambda g: g * scale, gsum)

        def client_update(ad, ost, bs):
            def step(carry, b):
                ad, ost = carry
                lval, grads = grads_of(ad, b)
                upd, ost = opt_update(grads, ost, ad, mask)
                ad = apply_updates(ad, upd)
                return (ad, ost), lval

            (ad, ost), losses = jax.lax.scan(step, (ad, ost), bs)
            return ad, ost, jnp.mean(losses)

        adapters, opt_state, losses = jax.vmap(client_update)(
            adapters, opt_state, batch)
        adapters = aggregate(adapters, acfg.mode)
        return adapters, opt_state, jnp.mean(losses)

    return fed_train_step


def shape_dtype_like_first_client(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), tree)


def build_train_entry(cfg, shape, mesh, acfg=None, local_steps=1,
                      microbatches=1, dtype=jnp.bfloat16):
    acfg = acfg or AdapterConfig()
    C = _dp_size(mesh)
    B_local = max(1, shape.global_batch // C)
    S = shape.seq_len

    params = abstract_model(cfg, dtype)
    adapters = abstract_adapters(cfg, acfg, n_clients=C)
    opt_init, _ = sgd(1e-2, 0.9)
    opt_state = jax.eval_shape(opt_init, adapters)  # client axis included

    batch = {"tokens": jax.ShapeDtypeStruct((C, local_steps, B_local, S),
                                            jnp.int32),
             "labels": jax.ShapeDtypeStruct((C, local_steps, B_local, S),
                                            jnp.int32)}
    if cfg.enc_dec:
        batch["frames"] = jax.ShapeDtypeStruct(
            (C, local_steps, B_local, cfg.enc_seq, cfg.d_model), dtype)

    p_specs = param_specs(cfg, params, mesh)
    a_specs = adapter_specs(cfg, adapters, mesh, client_axis=True)
    o_specs = jax.tree_util.tree_map(
        lambda leaf: _lookup_spec_for_opt(leaf, adapters, a_specs),
        opt_state)
    b_specs = batch_specs(batch, mesh)

    fn = make_fed_train_step(cfg, acfg, local_steps=local_steps,
                             microbatches=microbatches)
    return Entry(
        name="fed_train_step", fn=fn,
        args=(params, adapters, opt_state, batch),
        in_specs=(p_specs, a_specs, o_specs, b_specs),
        out_specs=(a_specs, o_specs, P()),
        donate_argnums=(1, 2))


def _lookup_spec_for_opt(leaf, adapters, a_specs):
    flat_a = jax.tree_util.tree_leaves(adapters)
    flat_s = jax.tree_util.tree_leaves(
        a_specs, is_leaf=lambda x: isinstance(x, P))
    for a, s in zip(flat_a, flat_s):
        if a.shape == leaf.shape and a.dtype == leaf.dtype:
            return s
    # f32 momentum of an f32 adapter leaf: match on shape only
    for a, s in zip(flat_a, flat_s):
        if a.shape == leaf.shape:
            return s
    return P()


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def make_prefill_step(cfg, acfg, max_seq, cache_dtype=jnp.bfloat16):
    def prefill_step(params, adapters, tokens, frames=None):
        logits, cache, _ = prefill(cfg, params, adapters, acfg, tokens,
                                   max_seq, enc_frames=frames,
                                   cache_dtype=cache_dtype)
        return logits, cache
    return prefill_step


def build_prefill_entry(cfg, shape, mesh, acfg=None, dtype=jnp.bfloat16):
    acfg = acfg or AdapterConfig()
    B, S = shape.global_batch, shape.seq_len
    params = abstract_model(cfg, dtype)
    adapters = abstract_adapters(cfg, acfg)

    args = [params, adapters,
            jax.ShapeDtypeStruct((B, S), jnp.int32)]
    fn = make_prefill_step(cfg, acfg, max_seq=S)
    if cfg.enc_dec:
        args.append(jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                         dtype))

    cache = jax.eval_shape(
        functools.partial(init_cache, cfg=cfg, batch_size=B, max_seq=S),
    )
    p_specs = param_specs(cfg, params, mesh)
    a_specs = adapter_specs(cfg, adapters, mesh, client_axis=False)
    dp_ok = B % _dp_size(mesh) == 0
    c_specs = cache_specs(cfg, cache, mesh, batch_over_dp=dp_ok)
    dp = dp_axis(mesh) if dp_ok else None
    tok_spec = P(dp, None)
    in_specs = [p_specs, a_specs, tok_spec]
    if cfg.enc_dec:
        in_specs.append(P(dp, None, None))
    logits_spec = P(dp, None, "model")
    return Entry(name="prefill_step", fn=fn, args=tuple(args),
                 in_specs=tuple(in_specs),
                 out_specs=(logits_spec, c_specs))


def make_serve_step(cfg, acfg):
    def serve_step(params, adapters, token, pos, cache):
        return decode_step(cfg, params, adapters, acfg, token, pos, cache)
    return serve_step


def build_decode_entry(cfg, shape, mesh, acfg=None, dtype=jnp.bfloat16):
    acfg = acfg or AdapterConfig()
    B, S = shape.global_batch, shape.seq_len
    params = abstract_model(cfg, dtype)
    adapters = abstract_adapters(cfg, acfg)
    cache = jax.eval_shape(
        functools.partial(init_cache, cfg=cfg, batch_size=B, max_seq=S))
    args = (params, adapters,
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
            cache)
    p_specs = param_specs(cfg, params, mesh)
    a_specs = adapter_specs(cfg, adapters, mesh, client_axis=False)
    dp_ok = B % _dp_size(mesh) == 0
    c_specs = cache_specs(cfg, cache, mesh, batch_over_dp=dp_ok)
    dp = dp_axis(mesh) if dp_ok else None
    in_specs = (p_specs, a_specs, P(dp, None), P(dp), c_specs)
    logits_spec = P(dp, None, "model")
    return Entry(name="serve_step", fn=make_serve_step(cfg, acfg),
                 args=args, in_specs=in_specs,
                 out_specs=(logits_spec, c_specs),
                 donate_argnums=(4,))


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

def build_entry(cfg, shape, mesh, acfg=None, **kw):
    """(cfg, InputShape, mesh) → Entry or None (recorded skip)."""
    if skip_reason(cfg, shape):
        return None
    cfg, note = variant_for_shape(cfg, shape)
    if shape.kind == "train":
        e = build_train_entry(cfg, shape, mesh, acfg, **kw)
    elif shape.kind == "prefill":
        e = build_prefill_entry(cfg, shape, mesh, acfg)
    else:
        e = build_decode_entry(cfg, shape, mesh, acfg)
    e.note = note
    return e


def sanitize_specs(shape_tree, spec_tree, mesh):
    """Drop mesh axes from any spec dimension they do not divide evenly
    (jit's argument-sharding path requires exact divisibility; GSPMD would
    otherwise pad). E.g. whisper's 51865-vocab embed cannot be 16-way
    sharded — it falls back to replicated on that dim."""
    def fix(leaf, spec):
        if spec is None:
            return None
        dims = []
        for d, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                dims.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            dims.append(ax if d % size == 0 else None)
        return P(*dims)

    return jax.tree_util.tree_map(
        fix, shape_tree, spec_tree)


def lower_entry(entry, mesh):
    """jit + lower under the mesh. Returns the Lowered object."""
    in_specs = sanitize_specs(entry.args, entry.in_specs, mesh)
    out_shape = jax.eval_shape(entry.fn, *entry.args)
    out_specs = sanitize_specs(out_shape, entry.out_specs, mesh)
    to_sharding = lambda spec_tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
    jitted = jax.jit(entry.fn,
                     in_shardings=to_sharding(in_specs),
                     out_shardings=to_sharding(out_specs),
                     donate_argnums=entry.donate_argnums)
    with mesh:
        return jitted.lower(*entry.args)
