# Launcher layer: production mesh, entry points, multi-pod dry-run.
