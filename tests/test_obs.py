"""repro.obs: metrics math vs numpy, trace schema round-trips, the
Prometheus exporter, per-request latency keys in engine reports, and the
instrumentation overhead guard (≤5% on the serving hot path)."""
import json
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import init_model
from repro.obs import (EVENT_SCHEMA, Histogram, MetricsRegistry, Timer,
                       TraceLog, sanitize, to_json, to_prometheus,
                       validate_exposition, validate_trace, write_metrics)
from repro.serving import AdapterRegistry, ServingConfig, ServingEngine
from repro.serving.demo import synthetic_clients

KEY = jax.random.PRNGKey(0)

LATENCY_REPORT_KEYS = [f"{k}_{s}_s"
                       for k in ("queue_wait", "ttft", "intertoken", "e2e")
                       for s in ("p50", "p90", "p99", "mean")]


def tiny_cfg():
    return reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    acfg = AdapterConfig(mode="fedsa", rank=4)
    params = init_model(KEY, cfg, jnp.float32)
    base = init_adapters(KEY, cfg, acfg)
    trees = [t["adapters"] for t in
             synthetic_clients({"adapters": base}, 4, seed=50, scale=0.05)]
    return cfg, acfg, params, base, trees


def make_engine(setup, *, metrics=None, trace=None, **kw):
    cfg, acfg, params, base, trees = setup
    reg = AdapterRegistry({"adapters": base}, n_slots=4)
    for i, t in enumerate(trees):
        reg.ingest(i, {"adapters": t})
    return ServingEngine(cfg, params, acfg, reg,
                         ServingConfig(max_batch=4, max_seq=32, **kw),
                         metrics=metrics, trace=trace)


def drive(engine, requests=6, new_tokens=6, seed=0):
    rng = np.random.default_rng(seed)
    for r in range(requests):
        engine.submit(r % 4, rng.integers(0, 512, int(rng.integers(4, 12))),
                      max_new_tokens=new_tokens)
    return engine.run()


# ---------------------------------------------------------------------------
# Histogram math
# ---------------------------------------------------------------------------

def test_histogram_percentiles_match_numpy():
    rng = np.random.default_rng(7)
    data = np.exp(rng.normal(-5.0, 1.2, size=20_000))   # ~latency-shaped
    h = Histogram("h")
    for v in data:
        h.observe(v)
    assert h.count == len(data)
    assert h.sum == pytest.approx(float(data.sum()), rel=1e-9)
    assert h.min == pytest.approx(float(data.min()))
    assert h.max == pytest.approx(float(data.max()))
    # worst-case relative error is one bucket ratio (10^(1/6) ≈ 1.47x);
    # with geometric interpolation the estimate lands far closer
    ratio = 10.0 ** (1.0 / 6.0)
    for q in (50, 90, 99):
        exact = float(np.percentile(data, q))
        est = h.percentile(q)
        assert exact / ratio <= est <= exact * ratio, (q, est, exact)
        assert est == pytest.approx(exact, rel=0.10)


def test_histogram_block_observe_and_bounds():
    h = Histogram("h")
    h.observe(0.01, n=7)                     # fused-decode block booking
    assert h.count == 7
    assert h.sum == pytest.approx(0.07)
    assert h.percentile(50) == pytest.approx(0.01, rel=1e-6)
    # out-of-range values land in the edge buckets; estimates stay
    # inside the matched bucket, clamped to the observed extremes
    h2 = Histogram("h2", lo=1e-3, hi=1.0)
    h2.observe(1e-9)
    h2.observe(50.0)
    assert 1e-9 <= h2.percentile(1) <= 1e-3   # underflow bucket
    assert h2.percentile(99) == pytest.approx(50.0)
    assert Histogram("e").percentile(50) is None


def test_counter_gauge_and_registry_semantics():
    m = MetricsRegistry()
    c = m.counter("c")
    g = m.gauge("g")
    h = m.histogram("h")
    assert m.counter("c") is c               # get-or-create shares
    with pytest.raises(TypeError):
        m.gauge("c")                         # a name may not change kind
    c.inc(3)
    g.set(0.5)
    h.observe(1.0)
    with pytest.raises(AssertionError):
        c.inc(-1)                            # counters are monotonic
    m.reset_window()                         # histograms/gauges reset...
    assert h.count == 0 and g.value == 0.0
    assert c.value == 3                      # ...counters never


def test_timer_records_into_histogram():
    m = MetricsRegistry()
    with m.timer("span_seconds") as t:
        pass
    assert t.elapsed >= 0.0
    assert m.histogram("span_seconds").count == 1
    plain = Timer()
    with plain:
        pass
    assert plain.elapsed >= 0.0


# ---------------------------------------------------------------------------
# Trace timeline
# ---------------------------------------------------------------------------

def test_trace_schema_round_trip():
    log = TraceLog(validate=True)
    log.current_tick = 3
    fill = {"rid": 1, "client": 0, "row": 0, "slot": 0, "queue_wait_s": 0.1,
            "bucket": 16, "rows": 2, "wall_s": 0.01, "ticks": 4,
            "version": 1, "blocking_rows": 1, "needed": 2, "free": 0,
            "from_ticks": 8, "to_ticks": 4, "tokens": 6, "ttft_s": 0.2,
            "e2e_s": 0.3, "kind": "dropout", "round": 2,
            "reason": "queue_full", "tier": "cold", "pages": 3, "page": 7}
    for ev, required in EVENT_SCHEMA.items():
        log.emit(ev, **{k: fill[k] for k in required})
    n, errors = validate_trace(log.to_jsonl())
    assert n == len(EVENT_SCHEMA)
    assert errors == []
    for rec in log:
        assert rec["tick"] == 3 and rec["ts"] >= 0.0


def test_trace_rejects_unknown_and_bounds():
    log = TraceLog(maxlen=2, validate=True)
    with pytest.raises(KeyError):
        log.emit("made_up_event", x=1)
    with pytest.raises(ValueError):
        log.emit("flip")                     # missing required version
    log.emit("flip", version=1)
    log.emit("flip", version=2)
    log.emit("flip", version=3)              # over maxlen: dropped
    assert len(log) == 2 and log.dropped == 1


def test_validate_trace_catches_bad_lines():
    n, errors = validate_trace('{"ev": "flip", "ts": NaN, "tick": 1}')
    assert errors                            # NaN is not strict JSON
    n, errors = validate_trace(
        '{"ev": "flip", "version": 1, "ts": 2.0, "tick": 1}\n'
        '{"ev": "flip", "version": 2, "ts": 1.0, "tick": 2}')
    assert any("backwards" in e for e in errors)
    n, errors = validate_trace('{"ev": "nope", "ts": 0.0, "tick": 0}')
    assert any("unknown" in e for e in errors)


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------

def test_prometheus_exposition_valid_and_cumulative():
    m = MetricsRegistry()
    m.counter("repro_c_total", "a counter").inc(5)
    m.gauge("repro_g", "a gauge").set(0.25)
    h = m.histogram("repro_h_seconds", "a histogram")
    for v in (1e-4, 1e-3, 1e-3, 0.5, 200.0):   # incl. +Inf overflow
        h.observe(v)
    text = to_prometheus(m)
    n, errors = validate_exposition(text)
    assert errors == [] and n > 0
    assert "# TYPE repro_c_total counter" in text
    assert "repro_c_total 5" in text
    assert 'repro_h_seconds_bucket{le="+Inf"} 5' in text
    assert "repro_h_seconds_count 5" in text
    # validator actually catches breakage
    broken = text.replace('le="+Inf"} 5', 'le="+Inf"} 4')
    _, errors = validate_exposition(broken)
    assert errors


def test_sanitize_and_json_snapshot_strict():
    nested = {"a": float("nan"), "b": [1.0, float("inf")],
              "c": {"d": -float("inf"), "e": 2}}
    clean = sanitize(nested)
    assert clean == {"a": None, "b": [1.0, None], "c": {"d": None, "e": 2}}
    m = MetricsRegistry()
    m.histogram("h")                         # empty: min/max/percentiles None
    m.counter("c").inc()
    json.dumps(to_json(m), allow_nan=False)  # must not raise


def test_write_metrics_formats(tmp_path):
    m = MetricsRegistry()
    m.counter("repro_c_total").inc(2)
    prom = write_metrics(tmp_path / "out.prom", m)
    _, errors = validate_exposition(prom.read_text())
    assert errors == []
    js = write_metrics(tmp_path / "out.json", m)
    assert json.loads(js.read_text())["counters"]["repro_c_total"] == 2


# ---------------------------------------------------------------------------
# Engine integration: report schema, counters, trace timeline
# ---------------------------------------------------------------------------

def test_engine_report_latency_schema_and_counters(setup):
    trace = TraceLog()
    engine = make_engine(setup, trace=trace)
    rep = drive(engine)
    for k in LATENCY_REPORT_KEYS:
        assert k in rep, f"report missing {k}"
        assert isinstance(rep[k], float) and rep[k] > 0.0, (k, rep[k])
    # ordering sanity: a request's e2e covers its ttft covers its queue wait
    assert rep["queue_wait_p50_s"] <= rep["ttft_p50_s"] <= rep["e2e_p50_s"]
    # report must serialize as STRICT json (no NaN/Infinity anywhere)
    json.dumps(sanitize(rep), allow_nan=False)
    snap = engine.metrics.snapshot()
    assert snap["counters"]["repro_serve_requests_total"] == rep["requests"]
    assert (snap["counters"]["repro_serve_tokens_decoded_total"]
            == rep["decode_tokens"])
    assert (snap["counters"]["repro_serve_tokens_prefilled_total"]
            == rep["prefill_tokens"])
    h = snap["histograms"]["repro_serve_e2e_seconds"]
    assert h["count"] == rep["requests"]

    # counters survive reset_stats() (lifetime-monotonic); histograms
    # re-window so the second pass's percentiles cover only that pass
    first_requests = rep["requests"]
    engine.reset_stats()
    assert engine.metrics.snapshot()["histograms"][
        "repro_serve_e2e_seconds"]["count"] == 0
    rep2 = drive(engine, seed=1)
    snap2 = engine.metrics.snapshot()
    assert (snap2["counters"]["repro_serve_requests_total"]
            == first_requests + rep2["requests"])
    assert snap2["histograms"]["repro_serve_e2e_seconds"][
        "count"] == rep2["requests"]

    # the trace carries the full request lifecycle, in valid JSONL
    n, errors = validate_trace(engine.trace.to_jsonl())
    assert errors == []
    evs = {e["ev"] for e in trace.events}
    assert {"submit", "admit", "prefill_batch", "decode_scan",
            "retire"} <= evs
    retires = trace.by_type("retire")
    assert len(retires) == first_requests + rep2["requests"]
    for r in retires:
        assert r["e2e_s"] >= r["ttft_s"] >= r["queue_wait_s"] >= 0.0
    # exposition of a real engine registry validates end to end
    _, errors = validate_exposition(to_prometheus(engine.metrics))
    assert errors == []


def test_engine_metrics_off_still_reports(setup):
    engine = make_engine(setup, metrics=False)
    assert engine.metrics is None
    rep = drive(engine)
    for k in LATENCY_REPORT_KEYS:
        assert rep[k] is None                # None, never NaN
    json.dumps(sanitize(rep), allow_nan=False)
    assert rep["requests"] == 6


def test_fused_decode_books_intertoken_blocks(setup):
    engine = make_engine(setup, decode_backend="fused", decode_ticks=4)
    rep = drive(engine, new_tokens=8)
    snap = engine.metrics.snapshot()
    itl = snap["histograms"]["repro_serve_intertoken_seconds"]
    # every decoded token books one inter-token gap, even though the
    # fused path only syncs once per T-token block
    assert itl["count"] == rep["decode_tokens"]
    assert rep["intertoken_p50_s"] > 0.0


# ---------------------------------------------------------------------------
# Overhead guard
# ---------------------------------------------------------------------------

def test_instrumentation_overhead_under_budget(setup):
    """Fully-instrumented engine (metrics + trace) must keep ≥95% of the
    uninstrumented engine's generation throughput on the same workload.
    Best-of-N with the arms interleaved: best-of sheds slow outliers,
    interleaving keeps shared-runner load drift from biasing one arm.
    Adaptive rounds (5 minimum, up to 12): noise can only make an arm
    look slower, and best-of is monotone in N, so extra rounds shed
    false failures on loaded runners without masking a real systematic
    overhead — that still fails every round. Throughput is measured on
    THIS process's CPU time (``time.process_time``), not wall clock —
    under pytest-xdist a preempted worker inflates wall time of
    whichever arm is running, while CPU time only books cycles the arm
    actually burned."""
    bare = make_engine(setup, metrics=False)
    instrumented = make_engine(setup, metrics=MetricsRegistry(),
                               trace=TraceLog())
    for engine in (bare, instrumented):      # warm-up: compiles
        drive(engine, requests=8, new_tokens=16)

    def one_pass(engine, seed):
        engine.reset_stats()
        t0 = time.process_time()
        rep = drive(engine, requests=8, new_tokens=16, seed=seed)
        cpu_s = time.process_time() - t0
        return rep["generated_tokens"] / cpu_s

    best = {id(bare): 0.0, id(instrumented): 0.0}
    for i in range(12):
        for engine in (bare, instrumented):
            best[id(engine)] = max(best[id(engine)], one_pass(engine, i))
        if i >= 4 and best[id(instrumented)] >= 0.95 * best[id(bare)]:
            break
    b, ins = best[id(bare)], best[id(instrumented)]
    assert ins >= 0.95 * b, (
        f"instrumentation overhead over budget: {ins:.1f} vs "
        f"{b:.1f} tok/s ({ins / b:.3f}x)")
