"""Selective aggregation invariants + the paper's aggregation-error algebra."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# real hypothesis when installed, skip-marking stubs otherwise
from conftest import given, settings, st  # noqa: F401

from repro.core.aggregation import aggregate, broadcast_clients
from repro.core.strategies import (FROZEN, LOCAL, SHARED, count_params,
                                   role_tree, trainable_mask)


def _client_tree(seed, C=4, d=6, r=2, dout=5):
    rng = np.random.default_rng(seed)
    leaf = lambda *s: jnp.asarray(rng.normal(size=(C,) + s).astype(np.float32))
    return {"wq": {"A": leaf(d, r), "B": leaf(r, dout)},
            "wv": {"A": leaf(d, r), "B": leaf(r, dout)},
            "cls_head": {"w": leaf(d, 3), "b": leaf(3)}}


@pytest.mark.parametrize("mode,a_role,b_role", [
    ("fedavg", SHARED, SHARED),
    ("ffa", FROZEN, SHARED),
    ("fedsa", SHARED, LOCAL),
])
def test_roles(mode, a_role, b_role):
    tree = _client_tree(0)
    roles = role_tree(tree, mode)
    assert roles["wq"]["A"] == a_role
    assert roles["wq"]["B"] == b_role
    assert roles["cls_head"]["w"] == SHARED


def test_fedsa_aggregates_A_keeps_B():
    tree = _client_tree(1)
    out = aggregate(tree, "fedsa")
    # A leaves: every client row equals the original cross-client mean
    want = jnp.mean(tree["wq"]["A"], axis=0)
    np.testing.assert_allclose(np.asarray(out["wq"]["A"][2]),
                               np.asarray(want), rtol=1e-6)
    # B leaves untouched
    np.testing.assert_array_equal(np.asarray(out["wq"]["B"]),
                                  np.asarray(tree["wq"]["B"]))


def test_fedavg_aggregates_everything():
    tree = _client_tree(2)
    out = aggregate(tree, "fedavg")
    for mod in ("wq", "wv"):
        for leaf in ("A", "B"):
            want = jnp.mean(tree[mod][leaf], axis=0)
            np.testing.assert_allclose(np.asarray(out[mod][leaf][0]),
                                       np.asarray(want), rtol=1e-6)


def test_participation_mask_keeps_nonparticipants():
    tree = _client_tree(3)
    part = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    out = aggregate(tree, "fedsa", participation=part)
    want = jnp.mean(tree["wq"]["A"][jnp.asarray([0, 2])], axis=0)
    np.testing.assert_allclose(np.asarray(out["wq"]["A"][0]),
                               np.asarray(want), rtol=1e-6)
    # non-participant keeps its own A
    np.testing.assert_array_equal(np.asarray(out["wq"]["A"][1]),
                                  np.asarray(tree["wq"]["A"][1]))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
def test_weighted_aggregation_is_convex_combination(C, seed):
    rng = np.random.default_rng(seed)
    tree = {"m": {"A": jnp.asarray(rng.normal(size=(C, 4, 2))
                                   .astype(np.float32))}}
    w = jnp.asarray(rng.uniform(0.1, 1.0, C).astype(np.float32))
    out = aggregate(tree, "fedsa", weights=w)["m"]["A"]
    want = jnp.tensordot(w / w.sum(), tree["m"]["A"], axes=(0, 0))
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    # result within the convex hull per coordinate
    lo = jnp.min(tree["m"]["A"], 0)
    hi = jnp.max(tree["m"]["A"], 0)
    assert bool(jnp.all(out[0] >= lo - 1e-5) and jnp.all(out[0] <= hi + 1e-5))


def test_aggregation_idempotent():
    tree = _client_tree(4)
    once = aggregate(tree, "fedsa")
    twice = aggregate(once, "fedsa")
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                rtol=1e-6), once, twice)


def test_comm_counts_fedsa_halves_fedavg():
    """Table 2's structure: FedSA communicates only A (+head) — half of
    vanilla LoRA's A+B per round; trainables equal LoRA's."""
    tree = {"wq": {"A": jnp.zeros((6, 4)), "B": jnp.zeros((4, 6))},
            "wv": {"A": jnp.zeros((6, 4)), "B": jnp.zeros((4, 6))}}
    tr_avg, comm_avg = count_params(tree, "fedavg")
    tr_sa, comm_sa = count_params(tree, "fedsa")
    tr_ffa, comm_ffa = count_params(tree, "ffa")
    assert comm_sa == comm_avg // 2 == comm_ffa
    assert tr_sa == tr_avg == 2 * tr_ffa


def test_ffa_equals_ideal_update():
    """FFA's claim: with A fixed = A0, mean(Bᵢ)·A0 == mean(Bᵢ·A0)."""
    rng = np.random.default_rng(5)
    C, k, r, d = 5, 4, 2, 6
    A0 = rng.normal(size=(r, d))
    Bs = rng.normal(size=(C, k, r))
    ideal = np.mean([Bs[i] @ A0 for i in range(C)], axis=0)
    agg = Bs.mean(0) @ A0
    np.testing.assert_allclose(agg, ideal, rtol=1e-10)


def test_fedavg_has_aggregation_error():
    """Eq. 27 vs Eq. 28: mean(Bᵢ)·mean(Aᵢ) ≠ mean(BᵢAᵢ) in general."""
    rng = np.random.default_rng(6)
    C, k, r, d = 5, 4, 2, 6
    As = rng.normal(size=(C, r, d))
    Bs = rng.normal(size=(C, k, r))
    ideal = np.mean([Bs[i] @ As[i] for i in range(C)], axis=0)
    fedavg = Bs.mean(0) @ As.mean(0)
    assert np.abs(fedavg - ideal).max() > 1e-2


def test_fedsa_update_matches_eq2():
    """After a FedSA round, client i's ΔW is Bᵢ · mean(A) (paper Eq. 2)."""
    tree = _client_tree(7)
    out = aggregate(tree, "fedsa")
    A_bar = jnp.mean(tree["wq"]["A"], axis=0)
    for i in range(4):
        dw = (out["wq"]["A"][i] @ out["wq"]["B"][i]).T   # our layout: (AB)ᵀ
        want = (A_bar @ tree["wq"]["B"][i]).T
        np.testing.assert_allclose(np.asarray(dw), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


def test_trainable_mask_freezes_ffa_A():
    tree = _client_tree(8)
    single = jax.tree_util.tree_map(lambda x: x[0], tree)
    mask = trainable_mask(single, "ffa")
    assert float(mask["wq"]["A"]) == 0.0
    assert float(mask["wq"]["B"]) == 1.0
    mask_sa = trainable_mask(single, "fedsa")
    assert float(mask_sa["wq"]["A"]) == 1.0


def test_broadcast_clients_shapes():
    single = {"x": jnp.ones((3, 2))}
    out = broadcast_clients(single, 5)
    assert out["x"].shape == (5, 3, 2)
    np.testing.assert_array_equal(np.asarray(out["x"][3]),
                                  np.asarray(single["x"]))
