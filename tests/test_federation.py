"""Host federated runtime: end-to-end rounds, similarity, comm accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import AdapterConfig, FedConfig, get_config, reduced
from repro.core import federation
from repro.core.similarity import pairwise_similarity, update_similarity
from repro.data.synthetic import make_classification_task


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("roberta-large"), n_layers=2, d_model=64)
    clients, tests = make_classification_task(
        n_clients=3, n_classes=4, vocab=cfg.vocab_size, seq=16,
        n_train=240, n_test=60, alpha=0.5, seed=0)
    test_batch = {k: jnp.asarray(np.stack([t[k][:32] for t in tests]))
                  for k in tests[0]}
    return cfg, clients, test_batch


@pytest.mark.parametrize("mode", ["fedavg", "ffa", "fedsa", "feddpa"])
def test_modes_train_and_improve(setup, mode):
    cfg, clients, test_batch = setup
    fed = FedConfig(n_clients=3, local_steps=3)
    acfg = AdapterConfig(mode=mode, rank=4)
    sys = federation.build(jax.random.PRNGKey(0), cfg, acfg, fed,
                           task="classification", n_classes=4, lr=5e-2)
    hist = federation.run_rounds(sys, clients, rounds=6, batch_size=16,
                                 seed=1, eval_every=6, test_batch=test_batch)
    assert hist["loss"][-1] < hist["loss"][0]
    assert np.isfinite(hist["loss"]).all()
    assert 0.0 <= hist["acc"][-1] <= 1.0


def test_fedsa_B_diverges_A_converges(setup):
    """After FedSA rounds on non-IID clients: aggregated A identical across
    clients (cos sim 1); local B diverged (cos sim < 1)."""
    cfg, clients, _ = setup
    fed = FedConfig(n_clients=3, local_steps=3)
    acfg = AdapterConfig(mode="fedsa", rank=4)
    sys = federation.build(jax.random.PRNGKey(0), cfg, acfg, fed,
                           task="classification", n_classes=4, lr=5e-2)
    federation.run_rounds(sys, clients, rounds=5, batch_size=16, seed=1)
    sims = pairwise_similarity(sys.trainables["adapters"])
    assert sims["A"] > 0.999, sims
    assert sims["B"] < 0.999, sims


def test_local_training_A_more_similar_than_B(setup):
    """Fig. 2's measurement: LOCAL-only training (no aggregation at all) →
    learned A matrices more similar across clients than B matrices."""
    cfg, clients, _ = setup
    fed = FedConfig(n_clients=3, local_steps=3)
    # fedavg mode but we never aggregate: call round pieces manually
    acfg = AdapterConfig(mode="fedsa", rank=4)
    sys = federation.build(jax.random.PRNGKey(0), cfg, acfg, fed,
                           task="classification", n_classes=4, lr=5e-2)
    # participation = 0 for everyone → the aggregation step is a no-op
    # (non-participants keep their leaves), i.e. pure local fine-tuning.
    tr, ost = sys.trainables, sys.opt_state
    from repro.data.synthetic import stack_client_batch
    rng = np.random.default_rng(2)
    for _ in range(8):
        steps = [stack_client_batch(clients, 16, rng) for _ in range(3)]
        batches = {k: jnp.asarray(np.stack([s[k] for s in steps], 1))
                   for k in steps[0]}
        part = jnp.zeros((3,), jnp.float32)
        tr, ost, _ = sys.round_fn(tr, ost, batches, part)
    init_ad = jax.tree_util.tree_map(lambda x: x[0],
                                     sys.trainables["adapters"])
    sims = pairwise_similarity(tr["adapters"])
    upd = update_similarity(tr["adapters"], init_ad)
    assert sims["A"] > sims["B"], sims          # the paper's Fig. 2 claim
    assert upd["A"] < 0.99999                   # A actually moved (Fig. 4)


def test_client_sampling_runs(setup):
    cfg, clients, test_batch = setup
    fed = FedConfig(n_clients=3, local_steps=2, client_sample_rate=0.5)
    acfg = AdapterConfig(mode="fedsa", rank=4)
    sys = federation.build(jax.random.PRNGKey(0), cfg, acfg, fed,
                           task="classification", n_classes=4, lr=2e-2)
    hist = federation.run_rounds(sys, clients, rounds=4, batch_size=8, seed=3)
    assert np.isfinite(hist["loss"]).all()


def test_lm_task_federation():
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)
    from repro.data.synthetic import make_lm_task
    clients, tests = make_lm_task(n_clients=2, vocab=cfg.vocab_size, seq=16,
                                  n_train=64, n_test=16)
    fed = FedConfig(n_clients=2, local_steps=2)
    acfg = AdapterConfig(mode="fedsa", rank=4)
    sys = federation.build(jax.random.PRNGKey(0), cfg, acfg, fed, task="lm",
                           lr=5e-2)
    hist = federation.run_rounds(sys, clients, rounds=4, batch_size=8, seed=1)
    assert hist["loss"][-1] < hist["loss"][0]


def test_comm_accounting_matches_strategy(setup):
    cfg, clients, _ = setup
    fed = FedConfig(n_clients=3, local_steps=1)
    built = {}
    for mode in ("fedavg", "ffa", "fedsa"):
        acfg = AdapterConfig(mode=mode, rank=4)
        built[mode] = federation.build(jax.random.PRNGKey(0), cfg, acfg, fed,
                                       task="classification", n_classes=4)
    # fedsa comm = ffa comm (= A-only vs B-only, same leaf sizes at sym rank)
    assert built["fedsa"].comm_per_round < built["fedavg"].comm_per_round
    assert built["fedsa"].n_trainable == built["fedavg"].n_trainable
