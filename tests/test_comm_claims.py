"""Communication claims across the architecture zoo (Table 2 structure +
the MLA asymmetry finding from EXPERIMENTS.md §Perf)."""
import pytest

from repro.configs import ASSIGNED, AdapterConfig
from repro.core.strategies import count_params
from repro.launch.entry import abstract_adapters


def _ratio(arch):
    from repro.configs import get_config
    cfg = get_config(arch)
    ad = abstract_adapters(cfg, AdapterConfig())
    _, c_sa = count_params(ad, "fedsa")
    _, c_av = count_params(ad, "fedavg")
    return c_sa / c_av


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_fedsa_uploads_strictly_less_than_fedavg(arch):
    """FedSA uploads only A: always < FedAvg's A+B, but the ratio is
    geometry-dependent — exactly ½ only when |A| == |B| (symmetric MHA);
    0.53–0.64 under GQA (A ∝ 2·d_model vs B ∝ (H+Hkv)·hd); 0.03 on MLA;
    ~0.38 on SSM in/out projections. (EXPERIMENTS.md §Perf.)"""
    assert _ratio(arch) < 1.0


def test_symmetric_mha_exactly_half():
    """d_in == d_out on both adapted modules (MHA: Hkv == H, H·hd == d)
    ⇒ |A| == |B| ⇒ ratio 0.5 — the paper's RoBERTa setting."""
    for arch in ("deepseek-7b", "stablelm-3b", "whisper-tiny"):
        assert abs(_ratio(arch) - 0.5) < 1e-9, arch


def test_gqa_ratio_between_half_and_two_thirds():
    for arch in ("qwen3-32b", "chameleon-34b", "minitron-4b",
                 "granite-moe-3b-a800m"):
        assert 0.5 < _ratio(arch) < 0.67, arch


def test_mla_asymmetry_amplifies_fedsa():
    """DeepSeek-V3's adapted modules (wq_b/wkv_b) have tiny latent inputs
    and huge H·head_dim outputs → FedSA uploads far less than half."""
    assert _ratio("deepseek-v3-671b") < 0.05


def test_ffa_equals_fedsa_upload_on_symmetric():
    from repro.configs import get_config
    cfg = get_config("deepseek-7b")
    ad = abstract_adapters(cfg, AdapterConfig())
    _, c_sa = count_params(ad, "fedsa")
    _, c_ffa = count_params(ad, "ffa")
    assert c_sa == c_ffa


def test_dryrun_records_complete():
    """All 80 (arch × shape × mesh) records exist and none failed."""
    import glob
    import json
    import os
    d = os.path.join(os.path.dirname(__file__), "..", "experiments",
                     "dryrun")
    files = glob.glob(os.path.join(d, "*.json"))
    if len(files) < 80:
        pytest.skip("dry-run matrix not generated in this checkout")
    statuses = {}
    for f in files:
        rec = json.load(open(f))
        statuses[os.path.basename(f)] = rec["status"]
    assert all(s in ("ok", "skipped") for s in statuses.values()), statuses
    assert sum(1 for s in statuses.values() if s == "skipped") == 2
