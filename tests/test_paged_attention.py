"""Paged decode-attention kernel (interpret mode) vs the jnp oracle and
the dense ``decode_attention`` path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.attention import decode_attention, paged_gather

KEY = jax.random.PRNGKey(3)


def _operands(B, Hkv, G, hd, page, P, n_pages, seed=0, dtype=jnp.float32):
    """Random pool + per-row block tables of distinct physical pages
    (page 0 left as the shared write-off page)."""
    assert n_pages > B * P, "need distinct pages per row + write-off"
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Hkv * G, hd), dtype)
    kp = jax.random.normal(ks[1], (n_pages, page, Hkv, hd), dtype)
    vp = jax.random.normal(ks[2], (n_pages, page, Hkv, hd), dtype)
    perm = np.random.default_rng(seed).permutation(n_pages - 1) + 1
    bt = jnp.asarray(perm[: B * P].reshape(B, P), jnp.int32)
    return q, kp, vp, bt


@pytest.mark.parametrize("shape", [
    # (B, Hkv, G, hd, page, P)
    (2, 2, 2, 16, 8, 4),
    (4, 1, 4, 32, 16, 2),
    (1, 2, 1, 8, 4, 8),
])
def test_paged_attention_matches_ref(shape):
    B, Hkv, G, hd, page, P = shape
    q, kp, vp, bt = _operands(B, Hkv, G, hd, page, P, n_pages=B * P + 3)
    pos = jnp.asarray(
        np.random.default_rng(1).integers(0, P * page, B), jnp.int32)
    y = ops.paged_attention(q, kp, vp, bt, pos)
    y0 = ref.paged_attention_ref(q, kp, vp, bt, pos)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-5, atol=1e-5)


def test_paged_attention_sliding_window():
    q, kp, vp, bt = _operands(3, 2, 2, 16, 8, 4, n_pages=16, seed=2)
    pos = jnp.array([5, 17, 31], jnp.int32)
    for window in (4, 9, 64):
        y = ops.paged_attention(q, kp, vp, bt, pos, window=window)
        y0 = ref.paged_attention_ref(q, kp, vp, bt, pos, window=window)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                                   rtol=1e-5, atol=1e-5, err_msg=str(window))


def test_paged_attention_writeoff_page_masked():
    """Table entries past a row's reservation point at the write-off page
    (id 0, shared across rows); positions mask them out of the softmax."""
    q, kp, vp, bt = _operands(2, 2, 2, 16, 8, 4, n_pages=12, seed=4)
    bt = bt.at[:, 2:].set(0)                    # only 2 real pages per row
    pos = jnp.array([3, 15], jnp.int32)         # within the real pages
    y = ops.paged_attention(q, kp, vp, bt, pos)
    y0 = ref.paged_attention_ref(q, kp, vp, bt, pos)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-5, atol=1e-5)
    # write-off contents must not leak: perturbing page 0 changes nothing
    y2 = ops.paged_attention(q, kp.at[0].add(7.0), vp.at[0].add(-3.0),
                             bt, pos)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))


def test_paged_matches_dense_decode_attention():
    """Paging a dense cache through an identity-ish block table must
    reproduce ``decode_attention`` exactly (same masked softmax)."""
    B, Hkv, G, hd, page, P = 3, 2, 2, 16, 8, 3
    S = page * P
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, 1, Hkv * G, hd), jnp.float32)
    k_dense = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    v_dense = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    pos = jnp.array([2, 11, 23], jnp.int32)
    want = decode_attention(q, k_dense, v_dense, pos)       # (B, 1, H*hd)

    # scatter the dense rows into a scrambled pool
    n_pages = 1 + B * P
    perm = np.random.default_rng(0).permutation(B * P) + 1
    bt = jnp.asarray(perm.reshape(B, P), jnp.int32)
    kp = jnp.zeros((n_pages, page, Hkv, hd), jnp.float32)
    vp = jnp.zeros((n_pages, page, Hkv, hd), jnp.float32)
    kp = kp.at[bt.reshape(-1)].set(k_dense.reshape(B * P, page, Hkv, hd))
    vp = vp.at[bt.reshape(-1)].set(v_dense.reshape(B * P, page, Hkv, hd))
    np.testing.assert_array_equal(np.asarray(paged_gather(kp, bt)),
                                  np.asarray(k_dense))

    got = ops.paged_attention(q[:, 0], kp, vp, bt, pos)
    np.testing.assert_allclose(np.asarray(got.reshape(B, 1, -1)),
                               np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [
    # (B, Hkv, G, hd, page, P)
    (2, 2, 2, 16, 8, 4),
    (4, 1, 4, 32, 16, 2),
    (1, 2, 1, 8, 4, 8),
])
def test_paged_attention_inkernel_append_matches_ref(shape):
    """k_new/v_new: the kernel writes the current row's slot into its
    VMEM block before attending — the pool may hold garbage at pos."""
    B, Hkv, G, hd, page, P = shape
    q, kp, vp, bt = _operands(B, Hkv, G, hd, page, P, n_pages=B * P + 3,
                              seed=7)
    pos = jnp.asarray(
        np.random.default_rng(8).integers(0, P * page, B), jnp.int32)
    ks = jax.random.split(jax.random.PRNGKey(9), 2)
    kn = jax.random.normal(ks[0], (B, Hkv, hd), jnp.float32)
    vn = jax.random.normal(ks[1], (B, Hkv, hd), jnp.float32)
    # poison the slots the append must overwrite: stale pool contents at
    # pos must never be attended
    phys = jnp.take_along_axis(bt, (pos // page)[:, None], axis=1)[:, 0]
    kp_bad = kp.at[phys, pos % page].set(1e3)
    vp_bad = vp.at[phys, pos % page].set(-1e3)
    y = ops.paged_attention(q, kp_bad, vp_bad, bt, pos, kn, vn)
    y0 = ref.paged_attention_ref(q, kp_bad, vp_bad, bt, pos, kn, vn)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-5, atol=1e-5)
    # ... and appending equals attending pre-scattered pools
    y1 = ops.paged_attention(q, kp.at[phys, pos % page].set(kn),
                             vp.at[phys, pos % page].set(vn), bt, pos)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y1),
                               rtol=1e-5, atol=1e-5)


def test_paged_attention_append_sliding_window():
    q, kp, vp, bt = _operands(3, 2, 2, 16, 8, 4, n_pages=16, seed=11)
    pos = jnp.array([5, 17, 31], jnp.int32)
    ks = jax.random.split(jax.random.PRNGKey(12), 2)
    kn = jax.random.normal(ks[0], (3, 2, 16), jnp.float32)
    vn = jax.random.normal(ks[1], (3, 2, 16), jnp.float32)
    for window in (4, 9, 64):
        y = ops.paged_attention(q, kp, vp, bt, pos, kn, vn, window=window)
        y0 = ref.paged_attention_ref(q, kp, vp, bt, pos, kn, vn,
                                     window=window)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=str(window))


def test_paged_attention_bf16():
    q, kp, vp, bt = _operands(2, 2, 2, 16, 8, 2, n_pages=8, seed=5,
                              dtype=jnp.bfloat16)
    pos = jnp.array([7, 13], jnp.int32)
    y = ops.paged_attention(q, kp, vp, bt, pos)
    y0 = ref.paged_attention_ref(q, kp, vp, bt, pos)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y0, np.float32),
                               rtol=2e-2, atol=2e-2)
