"""repro.serving: registry LRU semantics, scheduler slot reuse, and the
multi-tenant engine vs the naive one-client-at-a-time decode path."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import decode_step, init_model, prefill
from repro.serving import AdapterRegistry, Scheduler, ServingEngine
from repro.serving.demo import synthetic_clients

KEY = jax.random.PRNGKey(0)


def tiny_cfg():
    return reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    acfg = AdapterConfig(mode="fedsa", rank=4)
    params = init_model(KEY, cfg, jnp.float32)
    base = init_adapters(KEY, cfg, acfg)
    trees = [t["adapters"] for t in
             synthetic_clients({"adapters": base}, 5, seed=50, scale=0.05)]
    return cfg, acfg, params, base, trees


def make_registry(base, trees, n_slots):
    reg = AdapterRegistry({"adapters": base}, n_slots=n_slots)
    for i, t in enumerate(trees):
        reg.ingest(i, {"adapters": t})
    return reg


# ---------------------------------------------------------------------------
# AdapterRegistry
# ---------------------------------------------------------------------------

def test_registry_lru_admission_and_counters(setup):
    _, _, _, base, trees = setup
    reg = make_registry(base, trees, n_slots=2)
    s0 = reg.acquire(0, pin=False)
    s1 = reg.acquire(1, pin=False)
    assert {s0, s1} == {0, 1}
    assert (reg.hits, reg.misses, reg.evictions) == (0, 2, 0)
    assert reg.acquire(0, pin=False) == s0          # hit, no movement
    assert (reg.hits, reg.misses) == (1, 2)
    # client 1 is now LRU → admitting 2 evicts client 1, reuses its slot
    s2 = reg.acquire(2, pin=False)
    assert s2 == s1
    assert reg.evictions == 1
    # client 1 re-admission is a miss again and evicts the LRU (client 0)
    s1b = reg.acquire(1, pin=False)
    assert s1b == s0
    assert reg.misses == 4
    assert reg.stats["hit_rate"] == pytest.approx(1 / 5)


def test_registry_pinned_slots_not_evicted(setup):
    _, _, _, base, trees = setup
    reg = make_registry(base, trees, n_slots=2)
    reg.acquire(0)                                   # pinned
    reg.acquire(1)                                   # pinned
    order = list(reg._lru.items())
    counters = (reg.hits, reg.misses, reg.evictions)
    with pytest.raises(RuntimeError, match="pinned"):
        reg.acquire(2)                               # nothing evictable
    # a failed acquire must not corrupt the LRU order or the counters
    assert list(reg._lru.items()) == order
    assert (reg.hits, reg.misses, reg.evictions) == counters
    reg.release(0)
    s = reg.acquire(2)
    assert s is not None                             # took client 0's slot
    assert 0 not in reg._lru and 2 in reg._lru
    with pytest.raises(KeyError):
        reg.acquire(99)                              # never ingested


def test_registry_release_unknown_is_noop(setup):
    _, _, _, base, trees = setup
    reg = make_registry(base, trees, n_slots=2)
    reg.release(3)                                   # never admitted
    reg.release(99)                                  # never ingested
    s0 = reg.acquire(0)
    reg.release(0)
    reg.release(0)                                   # over-release: no-op
    assert reg._pins[s0] == 0
    # the slot is still evictable exactly once over-releases are ignored
    reg.acquire(1)
    s2 = reg.acquire(2)
    assert s2 == s0 and reg.evictions == 1


def test_registry_gather_roundtrip(setup):
    _, _, _, base, trees = setup
    reg = make_registry(base, trees, n_slots=3)
    s3 = reg.acquire(3, pin=False)
    s1 = reg.acquire(1, pin=False)
    got = reg.gather(np.array([s1, s3, s1]))["adapters"]
    want = {"one": trees[1], "three": trees[3]}

    def leaf_of(tree, seg, grp, name, ab):
        return np.asarray(tree["segments"][seg][grp][name][ab])

    for seg in range(len(base["segments"])):
        for grp, mods in trees[1]["segments"][seg].items():
            for name in mods:
                g = np.asarray(got["segments"][seg][grp][name]["B"])
                # rows 0, 2 → client 1; row 1 → client 3
                np.testing.assert_array_equal(
                    g[:, 0], leaf_of(want["one"], seg, grp, name, "B"))
                np.testing.assert_array_equal(
                    g[:, 1], leaf_of(want["three"], seg, grp, name, "B"))
                np.testing.assert_array_equal(g[:, 0], g[:, 2])
                # A is shared — no per-row axis
                a = np.asarray(got["segments"][seg][grp][name]["A"])
                np.testing.assert_array_equal(
                    a, leaf_of(want["one"], seg, grp, name, "A"))


def test_registry_rejects_per_client_A_modes(setup):
    _, _, _, base, _ = setup
    with pytest.raises(NotImplementedError):
        AdapterRegistry({"adapters": base}, n_slots=2, mode="feddpa")


def test_registry_rejects_non_matrix_local_leaves():
    """VeRA's LOCAL leaf is the b *vector* — no grouped gather path."""
    vera_like = {"adapters": {"segments": [
        {"attn": {"wq": {"d": jnp.ones((4,)), "b": jnp.zeros((8,))}}}]}}
    with pytest.raises(NotImplementedError):
        AdapterRegistry(vera_like, n_slots=2)


def test_engine_rejects_mla_configs(setup):
    _, acfg, _, base, trees = setup
    mla_cfg = reduced(get_config("deepseek-v3-671b"))
    assert mla_cfg.mla is not None
    reg = make_registry(base, trees, n_slots=2)
    with pytest.raises(NotImplementedError):
        ServingEngine(mla_cfg, None, acfg, reg, max_batch=2, max_seq=8)


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

def test_scheduler_row_and_slot_reuse(setup):
    _, _, _, base, trees = setup
    reg = make_registry(base, trees, n_slots=2)
    sched = Scheduler(max_batch=2)
    for i in range(4):
        sched.submit(i % 2, np.zeros(4, np.int32), max_new_tokens=1)
    first = sched.admit(reg)
    assert [s.row for s in first] == [0, 1]
    assert len(sched.queue) == 2
    assert sched.admit(reg) == []                   # batch full
    # finish row 0 → its row AND registry pin free up for the next request
    sched.active[0].generated.append(1)
    seq = sched.retire(0, reg)
    assert seq.done
    nxt = sched.admit(reg)
    assert len(nxt) == 1 and nxt[0].row == 0
    assert nxt[0].request.client_id == 0            # FIFO order preserved
    assert reg.stats["hits"] >= 1                   # client 0 slot reused


def test_scheduler_blocks_when_all_slots_pinned(setup):
    _, _, _, base, trees = setup
    reg = make_registry(base, trees, n_slots=1)
    sched = Scheduler(max_batch=2)
    sched.submit(0, np.zeros(4, np.int32))
    sched.submit(1, np.zeros(4, np.int32))
    got = sched.admit(reg)
    assert len(got) == 1                            # client 1 can't pin
    assert sched.queue[0].client_id == 1
    sched.active[got[0].row].generated = [0] * 16
    sched.retire(got[0].row, reg)
    assert len(sched.admit(reg)) == 1               # now it can


# ---------------------------------------------------------------------------
# Engine end-to-end
# ---------------------------------------------------------------------------

def test_engine_mixed_batch_matches_naive_per_client(setup):
    """The tentpole invariant: a mixed-client batched decode must produce
    EXACTLY the tokens each client's personalized model produces alone."""
    cfg, acfg, params, base, trees = setup
    n_clients, new_tokens, plen = 3, 5, 6
    reg = make_registry(base, trees, n_slots=2)     # force eviction churn
    eng = ServingEngine(cfg, params, acfg, reg, max_batch=2, max_seq=16)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, plen) for _ in range(4)]
    for i, p in enumerate(prompts):
        eng.submit(i % n_clients, p, max_new_tokens=new_tokens)
    rep = eng.run()
    assert rep["requests"] == 4
    assert rep["generated_tokens"] == 4 * new_tokens
    assert rep["prefill_tokens"] == 4 * plen
    assert rep["tokens"] == 4 * plen + rep["decode_tokens"]
    assert 0.0 < rep["batch_occupancy"] <= 1.0

    for rid, p in enumerate(prompts):
        ad = trees[rid % n_clients]
        toks = jnp.asarray(p[None].astype(np.int32))
        logits, cache, _ = prefill(cfg, params, ad, acfg, toks, 16,
                                   cache_dtype=jnp.float32)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        want = [int(tok[0, 0])]
        for s in range(new_tokens - 1):
            pos = jnp.full((1,), plen + s, jnp.int32)
            logits, cache = decode_step(cfg, params, ad, acfg, tok, pos,
                                        cache)
            tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
            want.append(int(tok[0, 0]))
        assert eng.finished[rid]["tokens"].tolist() == want, rid


def test_engine_rejects_oversized_requests(setup):
    cfg, acfg, params, base, trees = setup
    reg = make_registry(base, trees, n_slots=2)
    eng = ServingEngine(cfg, params, acfg, reg, max_batch=2, max_seq=8)
    with pytest.raises(AssertionError):
        eng.submit(0, np.zeros(6, np.int32), max_new_tokens=4)
