"""repro.serving: registry LRU semantics, scheduler slot reuse, and the
multi-tenant engine vs the naive one-client-at-a-time decode path."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import decode_step, init_model, prefill
from repro.serving import (AdapterRegistry, Scheduler, ServingConfig,
                           ServingEngine)
from repro.serving.demo import synthetic_clients

KEY = jax.random.PRNGKey(0)


def tiny_cfg():
    return reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    acfg = AdapterConfig(mode="fedsa", rank=4)
    params = init_model(KEY, cfg, jnp.float32)
    base = init_adapters(KEY, cfg, acfg)
    trees = [t["adapters"] for t in
             synthetic_clients({"adapters": base}, 5, seed=50, scale=0.05)]
    return cfg, acfg, params, base, trees


def make_registry(base, trees, n_slots):
    reg = AdapterRegistry({"adapters": base}, n_slots=n_slots)
    for i, t in enumerate(trees):
        reg.ingest(i, {"adapters": t})
    return reg


# ---------------------------------------------------------------------------
# AdapterRegistry
# ---------------------------------------------------------------------------

def test_registry_lru_admission_and_counters(setup):
    _, _, _, base, trees = setup
    reg = make_registry(base, trees, n_slots=2)
    s0 = reg.acquire(0, pin=False)
    s1 = reg.acquire(1, pin=False)
    assert {s0, s1} == {0, 1}
    assert (reg.hits, reg.misses, reg.evictions) == (0, 2, 0)
    assert reg.acquire(0, pin=False) == s0          # hit, no movement
    assert (reg.hits, reg.misses) == (1, 2)
    # client 1 is now LRU → admitting 2 evicts client 1, reuses its slot
    s2 = reg.acquire(2, pin=False)
    assert s2 == s1
    assert reg.evictions == 1
    # client 1 re-admission is a miss again and evicts the LRU (client 0)
    s1b = reg.acquire(1, pin=False)
    assert s1b == s0
    assert reg.misses == 4
    assert reg.stats["hit_rate"] == pytest.approx(1 / 5)


def test_registry_pinned_slots_not_evicted(setup):
    _, _, _, base, trees = setup
    reg = make_registry(base, trees, n_slots=2)
    reg.acquire(0)                                   # pinned
    reg.acquire(1)                                   # pinned
    order = list(reg._lru.items())
    counters = (reg.hits, reg.misses, reg.evictions)
    with pytest.raises(RuntimeError, match="pinned"):
        reg.acquire(2)                               # nothing evictable
    # a failed acquire must not corrupt the LRU order or the counters
    assert list(reg._lru.items()) == order
    assert (reg.hits, reg.misses, reg.evictions) == counters
    reg.release(0)
    s = reg.acquire(2)
    assert s is not None                             # took client 0's slot
    assert 0 not in reg._lru and 2 in reg._lru
    with pytest.raises(KeyError):
        reg.acquire(99)                              # never ingested


def test_registry_release_unknown_is_noop(setup):
    _, _, _, base, trees = setup
    reg = make_registry(base, trees, n_slots=2)
    reg.release(3)                                   # never admitted
    reg.release(99)                                  # never ingested
    s0 = reg.acquire(0)
    reg.release(0)
    reg.release(0)                                   # over-release: no-op
    assert reg._pins[s0] == 0
    # the slot is still evictable exactly once over-releases are ignored
    reg.acquire(1)
    s2 = reg.acquire(2)
    assert s2 == s0 and reg.evictions == 1


def test_registry_gather_roundtrip(setup):
    _, _, _, base, trees = setup
    reg = make_registry(base, trees, n_slots=3)
    s3 = reg.acquire(3, pin=False)
    s1 = reg.acquire(1, pin=False)
    got = reg.gather(np.array([s1, s3, s1]))["adapters"]
    want = {"one": trees[1], "three": trees[3]}

    def leaf_of(tree, seg, grp, name, ab):
        return np.asarray(tree["segments"][seg][grp][name][ab])

    for seg in range(len(base["segments"])):
        for grp, mods in trees[1]["segments"][seg].items():
            for name in mods:
                g = np.asarray(got["segments"][seg][grp][name]["B"])
                # rows 0, 2 → client 1; row 1 → client 3
                np.testing.assert_array_equal(
                    g[:, 0], leaf_of(want["one"], seg, grp, name, "B"))
                np.testing.assert_array_equal(
                    g[:, 1], leaf_of(want["three"], seg, grp, name, "B"))
                np.testing.assert_array_equal(g[:, 0], g[:, 2])
                # A is shared — no per-row axis
                a = np.asarray(got["segments"][seg][grp][name]["A"])
                np.testing.assert_array_equal(
                    a, leaf_of(want["one"], seg, grp, name, "A"))


def test_registry_rejects_modes_without_local_leaves(setup):
    """fedavg/ffa aggregate or freeze both matrices: every tenant would
    serve identical weights — nothing to pack, nothing to personalize."""
    _, _, _, base, _ = setup
    for mode in ("fedavg", "ffa"):
        with pytest.raises(ValueError, match="client-local"):
            AdapterRegistry({"adapters": base}, n_slots=2, mode=mode)


def test_registry_rejects_non_matrix_local_leaves():
    """VeRA's LOCAL leaf is the b *vector* — no grouped gather path."""
    vera_like = {"adapters": {"segments": [
        {"attn": {"wq": {"d": jnp.ones((4,)), "b": jnp.zeros((8,))}}}]}}
    with pytest.raises(NotImplementedError):
        AdapterRegistry(vera_like, n_slots=2)


# ---------------------------------------------------------------------------
# Per-client A slot tables (generic SGMV packing: fedit / feddpa)
# ---------------------------------------------------------------------------

def leaves_named(tree, name):
    return [np.asarray(leaf) for path, leaf in
            jax.tree_util.tree_flatten_with_path(tree)[0]
            if str(path[-1].key) == name]


def test_registry_fedit_packs_A_and_B_tables(setup):
    """Under fedit packing BOTH matrices are per-client: the gather must
    hand per-row A_i next to per-row B_i, slot-consistent."""
    _, _, _, base, _ = setup
    template = {"adapters": base}
    from repro.serving.demo import synthetic_clients
    trees = synthetic_clients(template, 4, mode="fedit", seed=9,
                              scale=0.05)
    reg = AdapterRegistry(template, n_slots=3, mode="fedit")
    assert reg.has_local_A
    for i, t in enumerate(trees):
        reg.ingest(i, t)
    s2 = reg.acquire(2, pin=False)
    s0 = reg.acquire(0, pin=False)
    got = reg.gather(np.array([s0, s2]))["adapters"]
    for name in ("A", "B"):
        flat = leaves_named(got, name)
        want0 = leaves_named(trees[0]["adapters"], name)
        want2 = leaves_named(trees[2]["adapters"], name)
        for g, w0, w2 in zip(flat, want0, want2):
            np.testing.assert_array_equal(g[:, 0], w0)
            np.testing.assert_array_equal(g[:, 1], w2)
            assert not np.array_equal(w0, w2)


def test_registry_feddpa_packs_personal_pair_only(setup):
    """FedDPA: the personal (A, B) pair is per-client (slot tables),
    the global pair stays SHARED (verbatim, no per-row axis)."""
    cfg, _, _, _, _ = setup
    acfg = AdapterConfig(mode="feddpa", rank=4)
    base = init_adapters(KEY, cfg, acfg)
    template = {"adapters": base}
    from repro.serving.demo import synthetic_clients
    trees = synthetic_clients(template, 3, mode="feddpa", seed=10,
                              scale=0.05)
    reg = AdapterRegistry(template, n_slots=2, mode="feddpa")
    assert reg.has_local_A
    for i, t in enumerate(trees):
        reg.ingest(i, t)
    s1 = reg.acquire(1, pin=False)
    got = reg.gather(np.array([s1]))["adapters"]
    flat_got = jax.tree_util.tree_flatten_with_path(got)[0]
    flat_want = jax.tree_util.tree_flatten_with_path(
        trees[1]["adapters"])[0]
    checked_personal = checked_global = 0
    for (path, g), (_, w) in zip(flat_got, flat_want):
        names = [str(p.key) for p in path if hasattr(p, "key")]
        if "personal" in names:
            assert g.ndim == w.ndim + 1          # gained the per-row axis
            np.testing.assert_array_equal(np.asarray(g)[:, 0],
                                          np.asarray(w))
            checked_personal += 1
        elif "global" in names:
            assert g.shape == w.shape            # shared: stored verbatim
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
            checked_global += 1
    assert checked_personal and checked_global


def test_registry_paired_tables_evict_and_pin_together(setup):
    """The satellite invariant: one slot index addresses a client's A
    AND B tables — eviction rewrites both, pinning protects both, and a
    resident tenant's pair is never torn (A from one client, B from
    another)."""
    _, _, _, base, _ = setup
    template = {"adapters": base}
    from repro.serving.demo import synthetic_clients
    trees = synthetic_clients(template, 4, mode="fedit", seed=12,
                              scale=0.05)
    reg = AdapterRegistry(template, n_slots=2, mode="fedit")
    for i, t in enumerate(trees):
        reg.ingest(i, t)

    def assert_pair(slot, client):
        got = reg.gather(np.array([slot]))["adapters"]
        for name in ("A", "B"):
            for g, w in zip(leaves_named(got, name),
                            leaves_named(trees[client]["adapters"], name)):
                np.testing.assert_array_equal(g[:, 0], w)

    s0 = reg.acquire(0)                          # pinned
    s1 = reg.acquire(1, pin=False)
    assert_pair(s0, 0)
    assert_pair(s1, 1)
    # eviction may only take the unpinned slot, and must rewrite BOTH
    # tables of that slot to the new client
    s2 = reg.acquire(2, pin=False)
    assert s2 == s1 and reg.evictions == 1
    assert_pair(s2, 2)
    assert_pair(s0, 0)                           # pinned pair untouched
    # pinned slot blocks admission entirely (neither table is reusable)
    reg.acquire(2)                               # pin the second slot too
    with pytest.raises(RuntimeError, match="pinned"):
        reg.acquire(3)
    # one release frees the PAIR at once — the next admission owns both
    reg.release(0)
    s3 = reg.acquire(3, pin=False)
    assert s3 == s0
    assert_pair(s3, 3)


def test_engine_rejects_mla_configs(setup):
    _, acfg, _, base, trees = setup
    mla_cfg = reduced(get_config("deepseek-v3-671b"))
    assert mla_cfg.mla is not None
    reg = make_registry(base, trees, n_slots=2)
    with pytest.raises(NotImplementedError):
        ServingEngine(mla_cfg, None, acfg, reg,
                      ServingConfig(max_batch=2, max_seq=8))


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

def test_scheduler_row_and_slot_reuse(setup):
    _, _, _, base, trees = setup
    reg = make_registry(base, trees, n_slots=2)
    sched = Scheduler(max_batch=2)
    for i in range(4):
        sched.submit(i % 2, np.zeros(4, np.int32), max_new_tokens=1)
    first = sched.admit(reg)
    assert [s.row for s in first] == [0, 1]
    assert len(sched.queue) == 2
    assert sched.admit(reg) == []                   # batch full
    # finish row 0 → its row AND registry pin free up for the next request
    sched.active[0].generated.append(1)
    seq = sched.retire(0, reg)
    assert seq.done
    nxt = sched.admit(reg)
    assert len(nxt) == 1 and nxt[0].row == 0
    assert nxt[0].request.client_id == 0            # FIFO order preserved
    assert reg.stats["hits"] >= 1                   # client 0 slot reused


def test_scheduler_blocks_when_all_slots_pinned(setup):
    _, _, _, base, trees = setup
    reg = make_registry(base, trees, n_slots=1)
    sched = Scheduler(max_batch=2)
    sched.submit(0, np.zeros(4, np.int32))
    sched.submit(1, np.zeros(4, np.int32))
    got = sched.admit(reg)
    assert len(got) == 1                            # client 1 can't pin
    assert sched.queue[0].client_id == 1
    sched.active[got[0].row].generated = [0] * 16
    sched.retire(got[0].row, reg)
    assert len(sched.admit(reg)) == 1               # now it can


# ---------------------------------------------------------------------------
# Engine end-to-end
# ---------------------------------------------------------------------------

def test_engine_mixed_batch_matches_naive_per_client(setup):
    """The tentpole invariant: a mixed-client batched decode must produce
    EXACTLY the tokens each client's personalized model produces alone."""
    cfg, acfg, params, base, trees = setup
    n_clients, new_tokens, plen = 3, 5, 6
    reg = make_registry(base, trees, n_slots=2)     # force eviction churn
    eng = ServingEngine(cfg, params, acfg, reg,
                        ServingConfig(max_batch=2, max_seq=16))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, plen) for _ in range(4)]
    for i, p in enumerate(prompts):
        eng.submit(i % n_clients, p, max_new_tokens=new_tokens)
    rep = eng.run()
    assert rep["requests"] == 4
    assert rep["generated_tokens"] == 4 * new_tokens
    assert rep["prefill_tokens"] == 4 * plen
    assert rep["tokens"] == 4 * plen + rep["decode_tokens"]
    assert 0.0 < rep["batch_occupancy"] <= 1.0

    for rid, p in enumerate(prompts):
        ad = trees[rid % n_clients]
        toks = jnp.asarray(p[None].astype(np.int32))
        logits, cache, _ = prefill(cfg, params, ad, acfg, toks, 16,
                                   cache_dtype=jnp.float32)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        want = [int(tok[0, 0])]
        for s in range(new_tokens - 1):
            pos = jnp.full((1,), plen + s, jnp.int32)
            logits, cache = decode_step(cfg, params, ad, acfg, tok, pos,
                                        cache)
            tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
            want.append(int(tok[0, 0]))
        assert eng.finished[rid]["tokens"].tolist() == want, rid


def test_engine_rejects_oversized_requests(setup):
    cfg, acfg, params, base, trees = setup
    reg = make_registry(base, trees, n_slots=2)
    eng = ServingEngine(cfg, params, acfg, reg,
                        ServingConfig(max_batch=2, max_seq=8))
    with pytest.raises(AssertionError):
        eng.submit(0, np.zeros(6, np.int32), max_new_tokens=4)


# ---------------------------------------------------------------------------
# Generic SGMV serving: mixed fleets + the sgmv lora_backend
# ---------------------------------------------------------------------------

def naive_tokens(cfg, acfg, params, ad, prompt, new_tokens, max_seq=16):
    """Reference greedy decode for one client's personalized model."""
    toks = jnp.asarray(np.asarray(prompt)[None].astype(np.int32))
    logits, cache, _ = prefill(cfg, params, ad, acfg, toks, max_seq,
                               cache_dtype=jnp.float32)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [int(tok[0, 0])]
    for s in range(new_tokens - 1):
        pos = jnp.full((1,), len(prompt) + s, jnp.int32)
        logits, cache = decode_step(cfg, params, ad, acfg, tok, pos, cache)
        tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out


@pytest.fixture(scope="module")
def mixed_setup(setup):
    """A mode-heterogeneous fleet: FedSA tenants (shared Ā) next to
    FedIT tenants (personal A_i) in ONE fedit-packed registry."""
    from repro.serving.demo import mixed_fleet
    cfg, acfg, params, base, _ = setup
    template = {"adapters": base}
    trees, modes = mixed_fleet(template, 4, seed=21, scale=0.05)
    assert set(modes) == {"fedsa", "fedit"}
    # the fedsa tenants really do share the template's Ā while the
    # fedit tenants own a personal A_i
    for t, m in zip(trees, modes):
        a_t = leaves_named(t["adapters"], "A")
        a_0 = leaves_named(base, "A")
        same = all(np.array_equal(x, y) for x, y in zip(a_t, a_0))
        assert same == (m == "fedsa")
    return cfg, acfg, params, template, trees, modes


def run_mixed(mixed_setup, lora_backend, n_slots=3, new_tokens=5):
    cfg, acfg, params, template, trees, modes = mixed_setup
    reg = AdapterRegistry(template, n_slots=n_slots, mode="fedit")
    for i, t in enumerate(trees):
        reg.ingest(i, t)
    eng = ServingEngine(cfg, params, acfg, reg,
                        ServingConfig(max_batch=3, max_seq=16,
                                      lora_backend=lora_backend))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, 6) for _ in range(5)]
    for i, p in enumerate(prompts):
        eng.submit(i % len(trees), p, max_new_tokens=new_tokens)
    rep = eng.run()
    return eng, rep, prompts


def test_mixed_fleet_token_parity_vs_per_client(mixed_setup):
    """The tentpole invariant: a grouped batch mixing FedSA rows (shared
    Ā) with FedIT rows (personal A_i) must produce EXACTLY the tokens
    each tenant's personalized model produces alone, sequentially."""
    cfg, acfg, params, _, trees, modes = mixed_setup
    eng, rep, prompts = run_mixed(mixed_setup, "jnp")
    assert rep["requests"] == 5
    assert rep["registry_mode"] == "fedit"
    assert 0.0 < rep["batch_occupancy"] <= 1.0
    for rid, p in enumerate(prompts):
        want = naive_tokens(cfg, acfg, params,
                            trees[rid % len(trees)]["adapters"], p, 5)
        assert eng.finished[rid]["tokens"].tolist() == want, \
            (rid, modes[rid % len(trees)])


def test_sgmv_backend_matches_jnp_engine(mixed_setup):
    """lora_backend="sgmv" (fused per-row-A kernel on decode, bgmv fast
    path where Ā is batch-global) must be token-identical to the grouped
    jnp gather engine on the same mixed fleet."""
    eng_jnp, _, _ = run_mixed(mixed_setup, "jnp")
    eng_sgmv, rep, _ = run_mixed(mixed_setup, "sgmv")
    assert rep["lora_backend"] == "sgmv"
    for rid in eng_jnp.finished:
        assert (eng_sgmv.finished[rid]["tokens"].tolist()
                == eng_jnp.finished[rid]["tokens"].tolist()), rid


def test_fused_decode_parity_and_observability(setup):
    """decode_backend="fused" is token-parity-exact with the per-tick
    engine, and report() exposes the fused-loop health counters: host
    syncs per generated token (~1/T instead of ~1/batch), mean ticks per
    fused scan, and the T-tick page windows reserved vs. used."""
    cfg, acfg, params, base, trees = setup
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, int(n))
               for n in (6, 13, 4, 9, 11)]

    def run(**kw):
        reg = make_registry(base, trees, n_slots=2)
        eng = ServingEngine(cfg, params, acfg, reg,
                            ServingConfig(max_batch=2, max_seq=32, **kw))
        for i, p in enumerate(prompts):
            eng.submit(i % 3, p, max_new_tokens=6)
        rep = eng.run()
        return rep, {r: eng.finished[r]["tokens"].tolist()
                     for r in eng.finished}

    rep0, want = run()
    rep1, got = run(decode_backend="fused", decode_ticks=4)
    assert got == want
    # per-tick: one host sync per decode step; fused: one per scan
    assert rep0["decode_backend"] == "per-tick"
    assert rep0["host_syncs"] == rep0["decode_steps"]
    assert rep1["decode_backend"] == "fused"
    assert rep1["decode_ticks"] == 4
    assert rep1["host_syncs"] < rep0["host_syncs"]
    assert rep1["host_syncs_per_token"] < rep0["host_syncs_per_token"]
    assert 1.0 < rep1["fused_ticks_mean"] <= 4.0
    assert rep1["fused_scans"] == rep1["host_syncs"]
    # both engines booked the same real tokens (pads never counted)
    assert rep1["decode_tokens"] == rep0["decode_tokens"]
    # the window accounting: reservations cover what was written (equal
    # here — no eos cuts a window short), and nothing spilled
    assert (rep1["pages_window_reserved"] >= rep1["pages_window_used"]
            > 0)
    assert rep1["fused_tick_shrinks"] == 0


def test_feddpa_engine_matches_per_client(setup):
    """FedDPA tenants (dual adapters, personal pair per client) serve
    through the same grouped loop: global pair shared, personal pair
    gathered per row."""
    cfg, _, params, _, _ = setup
    acfg = AdapterConfig(mode="feddpa", rank=4)
    base = init_adapters(KEY, cfg, acfg)
    template = {"adapters": base}
    from repro.serving.demo import synthetic_clients
    trees = synthetic_clients(template, 3, mode="feddpa", seed=31,
                              scale=0.05)
    reg = AdapterRegistry(template, n_slots=2, mode="feddpa")
    for i, t in enumerate(trees):
        reg.ingest(i, t)
    eng = ServingEngine(cfg, params, acfg, reg,
                        ServingConfig(max_batch=2, max_seq=16))
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab_size, 5) for _ in range(3)]
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new_tokens=4)
    eng.run()
    for rid, p in enumerate(prompts):
        want = naive_tokens(cfg, acfg, params, trees[rid]["adapters"], p, 4)
        assert eng.finished[rid]["tokens"].tolist() == want, rid
