"""Lemma 1: closed-form optima of one-sided LoRA fine-tuning.

Fine-tuning B with A = Q fixed:  B* = ΔW E[xxᵀ] Qᵀ (Q E[xxᵀ] Qᵀ)⁻¹  (data-
dependent). Fine-tuning A with B = U fixed (U invertible): A* = U⁻¹ ΔW
(data-INDEPENDENT). We verify both by gradient descent on the paper's
least-squares objective and against the closed forms, and verify the
asymmetry claim: A* is invariant to the input distribution, B* is not.
"""
import jax
import jax.numpy as jnp
import numpy as np


def _setup(seed, k=6, d=8, r=3, n=4096, aniso=None):
    rng = np.random.default_rng(seed)
    dw = rng.normal(size=(k, d)) / np.sqrt(d)
    x = rng.normal(size=(n, d))
    if aniso is not None:
        x = x * aniso  # per-feature scales → E[xxᵀ] ≠ I
    return jnp.asarray(dw), jnp.asarray(x)


def _sigma(x):
    return x.T @ x / x.shape[0]


def closed_form_B(dw, x, Q):
    s = _sigma(x)
    return dw @ s @ Q.T @ jnp.linalg.inv(Q @ s @ Q.T)


def closed_form_A(dw, U):
    return jnp.linalg.inv(U) @ dw


def _fit(dw, x, Q=None, U=None, steps=3000, lr=0.05):
    """Gradient descent on E‖ΔW x − (BA) x‖² with one side fixed."""
    k, d = dw.shape
    r = (Q.shape[0] if Q is not None else U.shape[1])
    y = x @ dw.T

    if Q is not None:
        p0 = jnp.zeros((k, r))
        def pred(B):
            return x @ (B @ Q).T
    else:
        p0 = jnp.zeros((r, d))
        def pred(A):
            return x @ (U @ A).T

    def loss(p):
        return jnp.mean(jnp.sum((y - pred(p)) ** 2, -1))

    g = jax.jit(jax.grad(loss))
    p = p0
    for _ in range(steps):
        p = p - lr * g(p)
    return p


def test_closed_form_B_optimal():
    dw, x = _setup(0, aniso=np.linspace(0.5, 2.0, 8))
    Q = jnp.asarray(np.random.default_rng(1).normal(size=(3, 8)))
    B_gd = _fit(dw, x, Q=Q)
    B_cf = closed_form_B(dw, x, Q)
    np.testing.assert_allclose(np.asarray(B_gd), np.asarray(B_cf),
                               atol=1e-3, rtol=1e-2)


def test_closed_form_A_optimal():
    dw, x = _setup(2, k=4, d=8, r=4, aniso=np.linspace(0.5, 2.0, 8))
    U = jnp.asarray(np.random.default_rng(3).normal(size=(4, 4))
                    + 2 * np.eye(4))
    A_gd = _fit(dw, x, U=U, steps=12000, lr=0.004)
    A_cf = closed_form_A(dw, U)
    np.testing.assert_allclose(np.asarray(A_gd), np.asarray(A_cf),
                               atol=1e-3, rtol=1e-2)


def test_asymmetry_A_data_independent_B_not():
    """The paper's Remark 1, directly."""
    dw, x1 = _setup(4, k=4, d=8, r=4, aniso=np.linspace(0.2, 1.0, 8))
    _, x2 = _setup(5, k=4, d=8, r=4, aniso=np.linspace(1.0, 3.0, 8))
    U = jnp.asarray(np.random.default_rng(6).normal(size=(4, 4))
                    + 2 * np.eye(4))
    Q = jnp.asarray(np.random.default_rng(7).normal(size=(4, 8)))
    # A* identical across distributions
    np.testing.assert_allclose(np.asarray(closed_form_A(dw, U)),
                               np.asarray(closed_form_A(dw, U)), atol=1e-12)
    # B* differs across distributions
    b1 = closed_form_B(dw, x1, Q)
    b2 = closed_form_B(dw, x2, Q)
    assert float(jnp.max(jnp.abs(b1 - b2))) > 1e-3


def test_b_closed_form_exact_when_full_rank():
    """r = k ⇒ B* reproduces ΔW exactly: BQ = ΔW (loss → 0)."""
    dw, x = _setup(8, k=3, d=8, r=3)
    Q = jnp.asarray(np.random.default_rng(9).normal(size=(3, 8)))
    B = closed_form_B(dw, x, Q)
    # residual orthogonality: (ΔW − BQ) Σ Qᵀ = 0
    s = _sigma(x)
    resid = (dw - B @ Q) @ s @ Q.T
    np.testing.assert_allclose(np.asarray(resid), 0.0, atol=1e-6)
