"""Per-architecture smoke tests: reduced variant, one forward/train step on
CPU, output shapes + finite values. The FULL configs are exercised only via
the dry-run (ShapeDtypeStructs, no allocation)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import (decode_step, forward_hidden,
                                      head_weight, init_model, loss_fn,
                                      prefill)

ARCHS = sorted(ASSIGNED)


import functools


@functools.lru_cache(maxsize=None)
def _setup_cached(name, variant, mode):
    return _setup_impl(name, variant, mode)


def _setup(name, variant="lora", mode="fedsa"):
    return _setup_cached(name, variant, mode)


def _setup_impl(name, variant="lora", mode="fedsa"):
    cfg = reduced(get_config(name))
    if cfg.moe is not None:  # dropless for determinism in smoke tests
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    acfg = AdapterConfig(variant=variant, mode=mode, rank=4, vera_rank=16)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg, jnp.float32)
    adapters = init_adapters(jax.random.PRNGKey(1), cfg, acfg)
    return cfg, acfg, params, adapters


def _batch(cfg, B=2, S=16):
    key = jax.random.PRNGKey(2)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model), jnp.float32) * 0.1
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(name):
    cfg, acfg, params, adapters = _setup(name)
    batch = _batch(cfg)
    hidden, aux, _, _ = forward_hidden(cfg, params, adapters, acfg,
                                       batch["tokens"],
                                       enc_frames=batch.get("frames"))
    assert hidden.shape == (2, 16, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden)))
    logits = hidden @ head_weight(cfg, params)
    assert logits.shape == (2, 16, cfg.vocab_size)


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_decreases_loss(name):
    cfg, acfg, params, adapters = _setup(name)
    batch = _batch(cfg)

    @jax.jit
    def step(ad):
        l, g = jax.value_and_grad(
            lambda a: loss_fn(cfg, params, a, acfg, batch))(ad)
        return l, jax.tree_util.tree_map(lambda p, gr: p - 0.1 * gr, ad, g)

    l0, adapters = step(adapters)
    for _ in range(3):
        l1, adapters = step(adapters)
    assert bool(jnp.isfinite(l0)) and bool(jnp.isfinite(l1))
    assert float(l1) < float(l0), (name, float(l0), float(l1))


@pytest.mark.parametrize("name", ARCHS)
def test_grads_flow_only_to_adapters(name):
    """Base params are frozen (stop_gradient): loss grad w.r.t. adapters is
    nonzero after warmup while base params never enter the diff set."""
    cfg, acfg, params, adapters = _setup(name)
    batch = _batch(cfg)
    # one step so B ≠ 0 (grads to A are zero at B == 0)
    g1 = jax.grad(lambda a: loss_fn(cfg, params, a, acfg, batch))(adapters)
    adapters = jax.tree_util.tree_map(lambda p, gr: p - 0.1 * gr,
                                      adapters, g1)
    g = jax.grad(lambda a: loss_fn(cfg, params, a, acfg, batch))(adapters)
    norms = [float(jnp.linalg.norm(x)) for x in jax.tree_util.tree_leaves(g)]
    assert sum(norms) > 0.0


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_consistency(name):
    cfg, acfg, params, adapters = _setup(name)
    adapters = jax.tree_util.tree_map(lambda x: x + 0.01, adapters)
    B, S, Smax = 2, 12, 16
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    frames = (jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model),
                                jnp.float32) * 0.1 if cfg.enc_dec else None)
    hidden, _, _, _ = forward_hidden(cfg, params, adapters, acfg, toks,
                                     enc_frames=frames)
    full_logits = (hidden @ head_weight(cfg, params)).astype(jnp.float32)
    logits_p, cache, _ = prefill(cfg, params, adapters, acfg, toks[:, :S - 1],
                                 Smax, enc_frames=frames,
                                 cache_dtype=jnp.float32)
    assert jnp.allclose(logits_p[:, 0], full_logits[:, S - 2], atol=1e-4)
    dec_logits, cache = decode_step(cfg, params, adapters, acfg,
                                    toks[:, S - 1:S],
                                    jnp.full((B,), S - 1, jnp.int32), cache)
    assert jnp.allclose(dec_logits[:, 0], full_logits[:, S - 1], atol=1e-3), \
        float(jnp.max(jnp.abs(dec_logits[:, 0] - full_logits[:, S - 1])))


@pytest.mark.parametrize("variant", ["rslora", "vera"])
def test_variants_smoke(variant):
    """FedSA-rsLoRA and FedSA-VeRA paths run on a dense arch."""
    cfg, acfg, params, adapters = _setup("deepseek-7b", variant=variant)
    batch = _batch(cfg)
    l = loss_fn(cfg, params, adapters, acfg, batch)
    assert bool(jnp.isfinite(l))
    g = jax.grad(lambda a: loss_fn(cfg, params, a, acfg, batch))(adapters)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in leaves)


def test_sliding_window_matches_full_when_window_covers():
    cfg, acfg, params, adapters = _setup("stablelm-3b")
    batch = _batch(cfg, S=12)
    h1, _, _, _ = forward_hidden(cfg, params, adapters, acfg,
                                 batch["tokens"], window=64)
    h0, _, _, _ = forward_hidden(cfg, params, adapters, acfg,
                                 batch["tokens"])
    assert jnp.allclose(h1, h0, atol=1e-5)


def test_sliding_window_changes_output_when_small():
    cfg, acfg, params, adapters = _setup("stablelm-3b")
    batch = _batch(cfg, S=16)
    h1, _, _, _ = forward_hidden(cfg, params, adapters, acfg,
                                 batch["tokens"], window=2)
    h0, _, _, _ = forward_hidden(cfg, params, adapters, acfg,
                                 batch["tokens"])
    assert not jnp.allclose(h1, h0, atol=1e-3)


def test_mtp_loss_included():
    cfg, acfg, params, adapters = _setup("deepseek-v3-671b")
    assert cfg.mtp_depth == 1 and "mtp" in params
    batch = _batch(cfg)
    l_with = loss_fn(cfg, params, adapters, acfg, batch, mtp_coef=0.3)
    l_without = loss_fn(cfg, params, adapters, acfg, batch, mtp_coef=0.0)
    assert float(l_with) != float(l_without)


def test_zamba2_shared_attention_weights():
    """Hybrid arch: ONE attention weight set, per-occurrence adapters."""
    cfg, _, params, adapters = _setup("zamba2-2.7b")
    assert "shared_attn" in params
    n_super = cfg.n_layers // cfg.attn_every
    assert adapters["segments"][0]["attn"]["attn"]["wq"]["A"].shape[0] \
        == n_super
