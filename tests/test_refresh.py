"""repro.serving.refresh: the live train→serve bridge.

The contract under test (ISSUE 3 acceptance): a mid-generation adapter
publish never changes the tokens of already-admitted sequences, while
newly admitted sequences pick up the new round's Ā/B_i with no engine
rebuild or batch drain; flips are deferred until every sequence reading
the target buffer retires; staleness is reported per tenant.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.core.strategies import LOCAL, leaf_role
from repro.models.transformer import decode_step, init_model, prefill
from repro.serving import (AdapterFeed, AdapterRegistry, ServingConfig,
                           ServingEngine)
from repro.serving.demo import synthetic_clients

KEY = jax.random.PRNGKey(0)
N_CLIENTS = 3


def tiny_cfg():
    return reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)


def perturb_shared(template, seed, scale=0.05):
    """A new round's template: fresh SHARED leaves (the aggregated Ā
    changes every round), LOCAL leaves untouched (redrawn per client by
    synthetic_clients)."""
    root = jax.random.PRNGKey(seed)

    def leaf(path, x):
        if leaf_role(path, "fedsa") == LOCAL:
            return x
        k = jax.random.fold_in(root, abs(hash(str(path))) % (2 ** 31))
        return (jax.random.normal(k, x.shape, jnp.float32)
                * scale).astype(x.dtype)

    return jax.tree_util.tree_map_with_path(leaf, template)


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    acfg = AdapterConfig(mode="fedsa", rank=4)
    params = init_model(KEY, cfg, jnp.float32)
    template0 = {"adapters": init_adapters(KEY, cfg, acfg)}
    # round-0 and round-1 client populations: different Ā AND different B_i
    trees0 = synthetic_clients(template0, N_CLIENTS, seed=50, scale=0.05)
    template1 = perturb_shared(template0, seed=60)
    trees1 = synthetic_clients(template1, N_CLIENTS, seed=61, scale=0.05)
    return cfg, acfg, params, template0, trees0, trees1


def make_registry(template, trees, n_slots=2):
    reg = AdapterRegistry(template, n_slots=n_slots, versioned=True)
    for i, t in enumerate(trees):
        reg.ingest(i, t)
    return reg


def naive_tokens(cfg, acfg, params, tree, prompt, new_tokens, max_seq=32):
    """Reference greedy decode for one client's personalized model."""
    ad = tree["adapters"]
    toks = jnp.asarray(np.asarray(prompt)[None].astype(np.int32))
    logits, cache, _ = prefill(cfg, params, ad, acfg, toks, max_seq,
                               cache_dtype=jnp.float32)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [int(tok[0, 0])]
    for s in range(new_tokens - 1):
        pos = jnp.full((1,), len(prompt) + s, jnp.int32)
        logits, cache = decode_step(cfg, params, ad, acfg, tok, pos, cache)
        tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out


# ---------------------------------------------------------------------------
# Registry-level: versioned gather + flip ordering
# ---------------------------------------------------------------------------

def test_versioned_gather_spans_buffers(setup):
    _, _, _, template0, trees0, trees1 = setup
    reg = make_registry(template0, trees0)
    s0 = reg.acquire(0, pin=False)
    assert reg.publish(1, {i: t for i, t in enumerate(trees1)})
    assert reg.version == 1 and reg.active_buf == 1
    s0b = reg.acquire(0, pin=False)              # re-admission, new buffer
    got = reg.gather(np.array([s0, s0b]), np.array([0, 1]))["adapters"]

    def leaves(tree, name):
        return [np.asarray(leaf) for path, leaf in
                jax.tree_util.tree_flatten_with_path(tree["adapters"])[0]
                if str(path[-1].key) == name]

    for name, rows in (("B", (leaves(trees0[0], "B"),
                              leaves(trees1[0], "B"))),
                       ("A", (leaves(trees0[0], "A"),
                              leaves(trees1[0], "A")))):
        flat = [np.asarray(leaf) for path, leaf in
                jax.tree_util.tree_flatten_with_path(got)[0]
                if str(path[-1].key) == name]
        for g, v0, v1 in zip(flat, rows[0], rows[1]):
            np.testing.assert_array_equal(g[:, 0], v0)   # row 0 → round 0
            np.testing.assert_array_equal(g[:, 1], v1)   # row 1 → round 1
            assert not np.array_equal(v0, v1)


def test_flip_deferred_until_buffer_drains(setup):
    _, _, _, template0, trees0, trees1 = setup
    reg = make_registry(template0, trees0)
    trees2 = [jax.tree_util.tree_map(lambda x: x * 2.0, t) for t in trees1]
    b0 = reg.retain_buffer()                     # in-flight row, round 0
    assert b0 == 0
    assert reg.publish(1, {i: t for i, t in enumerate(trees1)})
    assert (reg.version, reg.active_buf, reg.flips) == (1, 1, 1)
    b1 = reg.retain_buffer()                     # in-flight row, round 1
    assert b1 == 1
    # round 2 targets buffer 0, still held by the round-0 row → deferred
    assert not reg.publish(2, {i: t for i, t in enumerate(trees2)})
    assert reg.version == 1 and reg.stats["pending_version"] == 2
    assert not reg.try_flip()
    assert reg.deferred_flips >= 2
    reg.release_buffer(b0)                       # round-0 row retires
    assert reg.try_flip()
    assert (reg.version, reg.active_buf, reg.flips) == (2, 0, 2)
    assert reg.stats["pending_version"] is None
    reg.release_buffer(b1)


def test_publish_coalesces_and_ignores_stale(setup):
    _, _, _, template0, trees0, trees1 = setup
    reg = make_registry(template0, trees0)
    hold0 = reg.retain_buffer()                  # round-0 row on buffer 0
    assert reg.publish(1, {0: trees1[0]})        # buffer 1 free → flips
    assert reg.active_buf == 1
    trees2 = [jax.tree_util.tree_map(lambda x: x * 2.0, t) for t in trees1]
    trees3 = [jax.tree_util.tree_map(lambda x: x * 3.0, t) for t in trees1]
    # rounds 2 and 3 both target buffer 0, still held by the round-0 row
    assert not reg.publish(2, {0: trees2[0]})
    assert not reg.publish(3, {1: trees3[1]})    # coalesces on top
    assert not reg.publish(1, {0: trees0[0]})    # stale: ignored
    assert reg.stats["pending_version"] == 3
    reg.release_buffer(hold0)
    assert reg.try_flip()
    assert reg.version == 3
    # client 0 kept round-2 leaves (superseded only where round 3 wrote)
    got0 = reg._store[0][0]
    np.testing.assert_array_equal(
        got0, 2.0 * np.asarray(
            [leaf for path, leaf in
             jax.tree_util.tree_flatten_with_path(trees1[0])[0]
             if str(path[-1].key) == "B"][0]))
    assert reg._client_ver[0] == 3 and reg._client_ver[1] == 3


def test_reingest_refreshes_unpinned_resident_slot(setup):
    """A same-version re-ingest must reach the slot at the next unpinned
    acquire — the slot tag tracks cold-store writes, not just rounds."""
    _, _, _, template0, trees0, trees1 = setup
    for versioned in (False, True):
        reg = AdapterRegistry(template0, n_slots=2, versioned=versioned)
        reg.ingest(0, trees0[0])
        s = reg.acquire(0)
        reg.release(0)
        reg.ingest(0, trees1[0])                 # registry.version still 0
        assert reg.acquire(0, pin=False) == s    # hit, refreshed in place
        got = reg.gather(np.array([s]))["adapters"]
        want = [np.asarray(leaf) for path, leaf in
                jax.tree_util.tree_flatten_with_path(
                    trees1[0]["adapters"])[0]
                if str(path[-1].key) == "B"]
        flat = [np.asarray(leaf)[:, 0] for path, leaf in
                jax.tree_util.tree_flatten_with_path(got)[0]
                if str(path[-1].key) == "B"]
        for g, w in zip(flat, want):
            np.testing.assert_array_equal(g, w)


def test_personal_A_rounds_flip_pairs_atomically(setup):
    """Generic SGMV refresh: a fedit-packed versioned registry publishes
    per-client (A_i, B_i) PAIRS through the same double-buffered
    machinery — after a flip the gather must hand the new round's A and
    B together (never round-t A against round-t+1 B), while a row held
    on the old buffer keeps the old pair intact."""
    cfg, _, _, _, _, _ = setup
    acfg = AdapterConfig(mode="fedsa", rank=4)
    template = {"adapters": init_adapters(KEY, cfg, acfg)}
    trees0 = synthetic_clients(template, N_CLIENTS, mode="fedit", seed=70,
                               scale=0.05)
    trees1 = synthetic_clients(template, N_CLIENTS, mode="fedit", seed=71,
                               scale=0.05)
    reg = AdapterRegistry(template, n_slots=2, mode="fedit",
                          versioned=True)
    assert reg.has_local_A
    for i, t in enumerate(trees0):
        reg.ingest(i, t)
    s0 = reg.acquire(0, pin=False)
    hold = reg.retain_buffer()                   # in-flight row, round 0
    assert reg.publish(1, {i: t for i, t in enumerate(trees1)})
    assert reg.version == 1 and reg.active_buf == 1
    s0b = reg.acquire(0, pin=False)              # re-admission, new buffer
    got = reg.gather(np.array([s0, s0b]), np.array([0, 1]))["adapters"]

    def leaves(tree, name):
        return [np.asarray(leaf) for path, leaf in
                jax.tree_util.tree_flatten_with_path(tree["adapters"])[0]
                if str(path[-1].key) == name]

    for name in ("A", "B"):
        flat = [np.asarray(leaf) for path, leaf in
                jax.tree_util.tree_flatten_with_path(got)[0]
                if str(path[-1].key) == name]
        for g, v0, v1 in zip(flat, leaves(trees0[0], name),
                             leaves(trees1[0], name)):
            np.testing.assert_array_equal(g[:, 0], v0)   # row 0 → round 0
            np.testing.assert_array_equal(g[:, 1], v1)   # row 1 → round 1
            assert not np.array_equal(v0, v1)
    reg.release_buffer(hold)


def test_publish_requires_versioned():
    cfg = tiny_cfg()
    acfg = AdapterConfig(mode="fedsa", rank=4)
    template = {"adapters": init_adapters(KEY, cfg, acfg)}
    reg = AdapterRegistry(template, n_slots=2)
    with pytest.raises(RuntimeError, match="versioned"):
        reg.publish(1, {0: template})


# ---------------------------------------------------------------------------
# Engine-level: token parity + fresh-version pickup + staleness
# ---------------------------------------------------------------------------

def run_with_publish(setup, publish_at, kv_layout="paged", warm_steps=4,
                     **engine_kw):
    """Submit one long request at round 0; optionally publish round 1
    mid-generation; submit a second request after the publish.
    ``warm_steps`` must leave the first request still decoding at the
    publish (a fused engine generates up to decode_ticks tokens per
    step, so its callers warm fewer steps)."""
    cfg, acfg, params, template0, trees0, trees1 = setup
    reg = make_registry(template0, trees0)
    feed = AdapterFeed()
    eng = ServingEngine(cfg, params, acfg, reg,
                        ServingConfig(max_batch=2, max_seq=32,
                                      kv_layout=kv_layout, page_size=8,
                                      **engine_kw),
                        feed=feed)
    rng = np.random.default_rng(3)
    prompt_a = rng.integers(0, cfg.vocab_size, 6)
    prompt_b = rng.integers(0, cfg.vocab_size, 5)
    eng.submit(0, prompt_a, max_new_tokens=12)
    second = False
    for _ in range(warm_steps):
        eng.step()
    assert not eng.scheduler.idle     # the publish must land mid-stream
    if publish_at:
        feed.publish(1, jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *trees1))
    while not eng.scheduler.idle:
        eng.step()
        if publish_at and not second and reg.version == 1:
            eng.submit(1, prompt_b, max_new_tokens=4)
            second = True
    rep = eng.report()
    return eng, reg, rep, prompt_a, prompt_b


def test_mid_publish_token_parity_and_fresh_pickup(setup):
    """THE acceptance invariant: round-t sequences decode identically
    with or without a round-t+1 publish mid-generation; the sequence
    admitted after the flip serves the new round exactly."""
    cfg, acfg, params, template0, trees0, trees1 = setup
    eng0, _, _, prompt_a, _ = run_with_publish(setup, publish_at=False)
    eng1, reg, rep, _, prompt_b = run_with_publish(setup, publish_at=True)
    base = eng0.finished[0]["tokens"].tolist()
    assert eng1.finished[0]["tokens"].tolist() == base
    assert base == naive_tokens(cfg, acfg, params, trees0[0], prompt_a, 12)
    # the sequence admitted post-flip serves round 1's Ā AND B_1
    assert eng1.finished[1]["version"] == 1
    assert eng1.finished[1]["tokens"].tolist() == naive_tokens(
        cfg, acfg, params, trees1[1], prompt_b, 4)
    # no rebuild, no drain: the engine decoded a mixed-version batch
    assert rep["flips"] == 1 and rep["adapter_version"] == 1
    assert eng1.finished[0]["version"] == 0
    assert rep["batch_occupancy"] > 0.5


def test_mid_publish_token_parity_fused_decode(setup):
    """The fused loop defers feed drain + try_flip to scan boundaries,
    so a publish landing while T ticks are in flight must not touch the
    tokens of any admitted row — and the post-flip admission still picks
    up the new round exactly as the per-tick engine does."""
    cfg, acfg, params, template0, trees0, trees1 = setup
    engp, _, repp, prompt_a, prompt_b = run_with_publish(
        setup, publish_at=True)
    for layout in ("paged", "dense"):
        engf, reg, rep, _, _ = run_with_publish(
            setup, publish_at=True, kv_layout=layout, warm_steps=1,
            decode_backend="fused", decode_ticks=4)
        for rid in engp.finished:
            assert (engf.finished[rid]["tokens"].tolist()
                    == engp.finished[rid]["tokens"].tolist()), (layout, rid)
            assert (engf.finished[rid]["version"]
                    == engp.finished[rid]["version"]), (layout, rid)
        assert rep["flips"] == 1 and rep["adapter_version"] == 1
        # the fused run really did span the publish with fewer syncs
        assert rep["host_syncs"] < repp["host_syncs"]


def test_mid_publish_token_parity_dense_layout(setup):
    eng0, _, _, _, _ = run_with_publish(setup, publish_at=False,
                                        kv_layout="dense")
    eng1, _, rep, _, _ = run_with_publish(setup, publish_at=True,
                                          kv_layout="dense")
    assert (eng1.finished[0]["tokens"].tolist()
            == eng0.finished[0]["tokens"].tolist())
    assert rep["flips"] == 1


def test_staleness_stats(setup):
    eng, reg, rep, _, _ = run_with_publish(setup, publish_at=True)
    # the round-0 sequence kept decoding after the round-1 flip → stale
    assert rep["staleness_max"] >= 1
    assert rep["tenant_staleness"][0] >= 1       # client 0 was in flight
    assert rep["tenant_staleness"].get(1, 0) == 0  # admitted at round 1
    assert rep["staleness_mean"] > 0
    assert rep["publishes"] == 1 and rep["deferred_flips"] == 0
    assert reg.stats["tenant_versions"] == {i: 1 for i in range(N_CLIENTS)}


def test_engine_flip_defers_behind_two_generations(setup):
    """publish → flip only after retire: round 2 cannot flip while a
    round-0 sequence is still decoding (its buffer is the target)."""
    cfg, acfg, params, template0, trees0, trees1 = setup
    reg = make_registry(template0, trees0)
    feed = AdapterFeed()
    eng = ServingEngine(cfg, params, acfg, reg,
                        ServingConfig(max_batch=2, max_seq=32,
                                      kv_layout="paged", page_size=8),
                        feed=feed)
    rng = np.random.default_rng(4)
    eng.submit(0, rng.integers(0, cfg.vocab_size, 4), max_new_tokens=16)
    eng.step()                                   # admit at round 0, buf 0
    stack = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees1)
    feed.publish(1, stack)
    eng.step()                                   # flip → round 1 active
    assert reg.version == 1
    eng.submit(1, rng.integers(0, cfg.vocab_size, 4), max_new_tokens=16)
    eng.step()                                   # admit at round 1, buf 1
    feed.publish(2, jax.tree_util.tree_map(lambda x: x * 2.0, stack))
    versions = []
    while not eng.scheduler.idle:
        eng.step()
        versions.append((len(eng.finished), reg.version))
    # round 2 committed only once the round-0 sequence retired
    assert all(v == 1 for done, v in versions if done == 0)
    assert reg.version == 2
    assert reg.deferred_flips > 0
    assert eng.finished[0]["version"] == 0 and eng.finished[1]["version"] == 1
