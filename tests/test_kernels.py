"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("M,K,N,r", [
    (128, 128, 128, 4), (256, 512, 128, 8), (128, 384, 256, 16),
    (512, 256, 384, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_matmul_sweep(M, K, N, r, dtype):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (M, K), dtype)
    w = (jax.random.normal(ks[1], (K, N), jnp.float32) * 0.05).astype(dtype)
    a = (jax.random.normal(ks[2], (K, r), jnp.float32) * 0.05).astype(dtype)
    b = (jax.random.normal(ks[3], (r, N), jnp.float32) * 0.05).astype(dtype)
    y = ops.lora_matmul(x, w, a, b, 2.0, bm=128, bn=128, bk=128)
    y0 = ref.lora_matmul_ref(x, w, a, b, 2.0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y0, np.float32), **_tol(dtype))


@pytest.mark.parametrize("blocks", [(128, 128, 128), (256, 128, 256),
                                    (128, 256, 512)])
def test_lora_matmul_block_shapes(blocks):
    bm, bn, bk = blocks
    M, K, N, r = 256, 512, 256, 8
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (M, K), jnp.float32)
    w = jax.random.normal(ks[1], (K, N), jnp.float32) * 0.05
    a = jax.random.normal(ks[2], (K, r), jnp.float32) * 0.05
    b = jax.random.normal(ks[3], (r, N), jnp.float32) * 0.05
    y = ops.lora_matmul(x, w, a, b, 1.5, bm=bm, bn=bn, bk=bk)
    y0 = ref.lora_matmul_ref(x, w, a, b, 1.5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,S,D,N", [(1, 32, 32, 8), (2, 64, 64, 16),
                                     (2, 128, 32, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_sweep(B, S, D, N, dtype):
    ks = jax.random.split(KEY, 3)
    a = jax.random.uniform(ks[0], (B, S, D, N), jnp.float32,
                           0.5, 0.999).astype(dtype)
    b = (jax.random.normal(ks[1], (B, S, D, N), jnp.float32) * 0.1
         ).astype(dtype)
    c = jax.random.normal(ks[2], (B, S, N), jnp.float32).astype(dtype)
    y = ops.ssm_scan(a, b, c, bd=min(32, D), chunk=16)
    y0, _ = ref.ssm_scan_ref(a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("B,S,D,N", [(1, 32, 32, 8), (2, 64, 64, 16)])
def test_ssm_scan_fused_matches_xla_scan(B, S, D, N):
    """The production fused kernel (raw dt/x/B/C/A inputs, a/b formed in
    VMEM) vs the XLA chunked scan used by the model."""
    from repro.models.mamba import selective_scan
    ks = jax.random.split(KEY, 5)
    dt = jax.random.uniform(ks[0], (B, S, D), jnp.float32, 0.01, 0.3)
    x = jax.random.normal(ks[1], (B, S, D), jnp.float32)
    bm = jax.random.normal(ks[2], (B, S, N), jnp.float32) * 0.3
    c = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    A = -jax.random.uniform(ks[4], (D, N), jnp.float32, 0.5, 2.0)
    y_k, h_k = ops.ssm_scan_fused(dt, x, bm, c, A, bd=min(32, D), chunk=16)
    y_r, h_r = selective_scan(dt, x, bm, c, A, 16)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,S,nh,hd,N", [(1, 32, 4, 16, 8), (2, 64, 8, 8, 16)])
def test_ssd_scan_fused_matches_xla_scan(B, S, nh, hd, N):
    """Mamba2 SSD fused kernel vs the XLA chunked scan."""
    from repro.models.mamba2 import ssd_scan
    ks = jax.random.split(KEY, 5)
    dt = jax.random.uniform(ks[0], (B, S, nh), jnp.float32, 0.01, 0.3)
    x = jax.random.normal(ks[1], (B, S, nh, hd), jnp.float32)
    bm = jax.random.normal(ks[2], (B, S, nh, N), jnp.float32) * 0.3
    c = jax.random.normal(ks[3], (B, S, nh, N), jnp.float32)
    A = -jax.random.uniform(ks[4], (nh,), jnp.float32, 0.5, 2.0)
    y_k, h_k = ops.ssd_scan_fused(dt, x, bm, c, A, bh=min(4, nh), chunk=16)
    y_r, h_r = ssd_scan(dt, x, bm, c, A, 16)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=1e-5, atol=1e-5)


def test_mamba2_pallas_backend_matches_xla():
    import dataclasses
    from repro.configs import AdapterConfig, get_config, reduced
    from repro.models.transformer import forward_hidden, init_model
    cfg = reduced(get_config("zamba2-2.7b"))
    cfgp = dataclasses.replace(
        cfg, ssm=dataclasses.replace(cfg.ssm, backend="pallas"))
    params = init_model(KEY, cfg, jnp.float32)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    h1, _, _, _ = forward_hidden(cfg, params, None, AdapterConfig(), toks)
    h2, _, _, _ = forward_hidden(cfgp, params, None, AdapterConfig(), toks)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)


def test_pallas_attention_backend_matches_xla():
    """Model-level: cfg.attn_backend='pallas' routes through the flash
    kernel and must match the XLA blockwise path exactly."""
    import dataclasses
    from repro.configs import AdapterConfig, get_config, reduced
    from repro.models.transformer import forward_hidden, init_model
    cfg = reduced(get_config("deepseek-7b"))
    cfgp = dataclasses.replace(cfg, attn_backend="pallas")
    params = init_model(KEY, cfg, jnp.float32)
    toks = jax.random.randint(KEY, (2, 64), 0, cfg.vocab_size)
    h1, _, _, _ = forward_hidden(cfg, params, None, AdapterConfig(), toks)
    h2, _, _, _ = forward_hidden(cfgp, params, None, AdapterConfig(), toks)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-5, atol=1e-5)


def test_ssm_scan_state_carries_across_chunks():
    """Decay ≈ 1 makes the state long-lived: any chunk-boundary bug shows."""
    B, S, D, N = 1, 64, 32, 8
    a = jnp.full((B, S, D, N), 0.999, jnp.float32)
    b = jnp.ones((B, S, D, N), jnp.float32) * 0.01
    c = jnp.ones((B, S, N), jnp.float32)
    y = ops.ssm_scan(a, b, c, bd=32, chunk=8)
    y0, _ = ref.ssm_scan_ref(a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0), rtol=1e-5)


@pytest.mark.parametrize("B,H,S,d", [(1, 2, 128, 64), (2, 4, 256, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, S, d, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, d), jnp.float32)
    y = ops.flash_attention(q, k, v, causal=causal, bq=64, bkv=64)
    y0 = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_window():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 256, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 256, 32), jnp.float32)
    y = ops.flash_attention(q, k, v, window=64, bq=64, bkv=64)
    y0 = ref.flash_attention_ref(q, k, v, window=64)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_decode_offset():
    """Sq < T (decode): causal mask must offset query positions."""
    ks = jax.random.split(KEY, 3)
    T, Sq = 256, 64
    q = jax.random.normal(ks[0], (1, 2, Sq, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, T, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, T, 32), jnp.float32)
    y = ops.flash_attention(q, k, v, bq=64, bkv=64)
    y0 = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_bf16():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.bfloat16)
    y = ops.flash_attention(q, k, v, bq=64, bkv=64)
    y0 = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y0, np.float32),
                               rtol=3e-2, atol=3e-2)
