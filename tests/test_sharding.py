"""Sharding rules + entry builders: spec validity for every arch (no
512-device compile here — that is launch/dryrun's job; these tests verify
the spec trees are structurally sound and a 1×1 host mesh lowers)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import (ASSIGNED, AdapterConfig, get_config, get_shape,
                           reduced)
from repro.launch.entry import (abstract_adapters, abstract_model,
                                build_entry, lower_entry, sanitize_specs,
                                skip_reason)
from repro.launch.mesh import make_host_mesh
from repro.sharding.rules import adapter_specs, cache_specs, param_specs

ARCHS = sorted(ASSIGNED)


class FakeMesh:
    """Shape-only stand-in for spec construction (no devices needed)."""
    def __init__(self, multi_pod=False):
        self.axis_names = (("pod", "data", "model") if multi_pod
                           else ("data", "model"))
        self.shape = dict(zip(self.axis_names,
                              (2, 16, 16) if multi_pod else (16, 16)))


@pytest.mark.parametrize("name", ARCHS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_cover_all_leaves(name, multi_pod):
    cfg = get_config(name)
    mesh = FakeMesh(multi_pod)
    params = abstract_model(cfg)
    specs = param_specs(cfg, params, mesh)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim, (leaf.shape, spec)
        for ax in spec:
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            for a in axes:
                assert a in mesh.axis_names


@pytest.mark.parametrize("name", ARCHS)
def test_sanitized_specs_divisible(name):
    cfg = get_config(name)
    mesh = FakeMesh()
    params = abstract_model(cfg)
    specs = sanitize_specs(params, param_specs(cfg, params, mesh), mesh)
    for leaf, spec in zip(
            jax.tree_util.tree_leaves(params),
            jax.tree_util.tree_leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P))):
        for d, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert d % size == 0, (name, leaf.shape, spec)


@pytest.mark.parametrize("name", ARCHS)
def test_adapter_specs_client_axis(name):
    cfg = get_config(name)
    mesh = FakeMesh(multi_pod=True)
    ad = abstract_adapters(cfg, AdapterConfig(), n_clients=32)
    specs = adapter_specs(cfg, ad, mesh, client_axis=True)
    for leaf, spec in zip(
            jax.tree_util.tree_leaves(ad),
            jax.tree_util.tree_leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P))):
        assert spec[0] == ("pod", "data"), (leaf.shape, spec)


@pytest.mark.parametrize("name", ARCHS)
@pytest.mark.parametrize("shape_name",
                         ["train_4k", "prefill_32k", "decode_32k",
                          "long_500k"])
def test_entries_build_for_all_pairs(name, shape_name):
    """Entry construction (ShapeDtypeStructs + specs) for all 40 pairs.
    Does not compile — the dry-run does; this catches structural bugs
    fast."""
    cfg = get_config(name)
    shape = get_shape(shape_name)
    mesh = FakeMesh()
    entry = build_entry(cfg, shape, mesh, AdapterConfig())
    if skip_reason(cfg, shape):
        assert entry is None
        return
    # arg / spec trees must be congruent
    for args, specs in zip(entry.args, entry.in_specs):
        na = len(jax.tree_util.tree_leaves(args))
        ns = len(jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)))
        assert na == ns


def test_host_mesh_end_to_end_tiny():
    """A REAL lower+compile+execute of the federated train step on the 1×1
    host mesh with a tiny model — semantic check of the in-mesh runtime."""
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)
    mesh = make_host_mesh()
    from repro.configs.base import InputShape
    shape = InputShape("tiny_train", seq_len=32, global_batch=2, kind="train")
    entry = build_entry(cfg, shape, mesh, AdapterConfig(rank=4))
    lowered = lower_entry(entry, mesh)
    compiled = lowered.compile()
    # run it with real zeros
    args = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), entry.args)
    out = compiled(*args)
    adapters, opt_state, loss = out
    assert bool(jnp.isfinite(loss))


def test_fed_train_step_aggregates_A_in_mesh():
    """After one in-mesh round, FedSA leaves client A's identical and B's
    (zero-init but updated) potentially different."""
    cfg = reduced(get_config("stablelm-3b"), n_layers=2, d_model=64)
    mesh = make_host_mesh()
    from repro.configs.base import InputShape
    shape = InputShape("tiny_train", seq_len=16, global_batch=2, kind="train")
    entry = build_entry(cfg, shape, mesh, AdapterConfig(rank=4),
                        local_steps=2)
    lowered = lower_entry(entry, mesh)
    compiled = lowered.compile()
    params, adapters, opt_state, batch = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), entry.args)
    # real params + distinct per-client tokens
    from repro.models.transformer import init_model
    from repro.core.adapters import init_adapters
    from repro.core.aggregation import broadcast_clients
    params = init_model(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    single = init_adapters(jax.random.PRNGKey(1), cfg, AdapterConfig(rank=4))
    C = batch["tokens"].shape[0]
    adapters = broadcast_clients(single, C)
    batch = dict(batch)
    batch["tokens"] = jax.random.randint(jax.random.PRNGKey(2),
                                         batch["tokens"].shape, 0,
                                         cfg.vocab_size)
    batch["labels"] = jax.random.randint(jax.random.PRNGKey(3),
                                         batch["labels"].shape, 0,
                                         cfg.vocab_size)
    new_ad, _, loss = compiled(params, adapters, opt_state, batch)
    assert bool(jnp.isfinite(loss))
    # A leaves equal across clients (aggregated)
    A = new_ad["segments"][0]["attn"]["wq"]["A"]
    if C > 1:
        np.testing.assert_allclose(np.asarray(A[0]), np.asarray(A[-1]),
                                   rtol=1e-5)


@pytest.mark.parametrize("name", ["falcon-mamba-7b", "qwen3-32b"])
def test_cache_specs_structure(name):
    cfg = get_config(name)
    mesh = FakeMesh()
    from repro.models.transformer import init_cache
    import functools
    cache = jax.eval_shape(functools.partial(init_cache, cfg=cfg,
                                             batch_size=16, max_seq=128))
    specs = cache_specs(cfg, cache, mesh)
    n_c = len(jax.tree_util.tree_leaves(cache))
    n_s = len(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_c == n_s
