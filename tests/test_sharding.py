"""Sharding rules + entry builders: spec validity for every arch (no
512-device compile here — that is launch/dryrun's job; these tests verify
the spec trees are structurally sound and a 1×1 host mesh lowers)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import (ASSIGNED, AdapterConfig, get_config, get_shape,
                           reduced)
from repro.launch.entry import (abstract_adapters, abstract_model,
                                build_entry, lower_entry, sanitize_specs,
                                skip_reason)
from repro.launch.mesh import make_host_mesh, make_mesh
from repro.sharding.rules import (adapter_specs, cache_specs,
                                  paged_cache_specs, param_specs,
                                  serving_table_specs)

ARCHS = sorted(ASSIGNED)


class FakeMesh:
    """Shape-only stand-in for spec construction (no devices needed).
    ``shape`` overrides the production extents — the small-mesh
    divisibility tests below run the same rules on (2, 2) and (1, 4)."""
    def __init__(self, multi_pod=False, shape=None):
        if shape is None:
            shape = (2, 16, 16) if multi_pod else (16, 16)
        self.axis_names = (("pod", "data", "model") if len(shape) == 3
                           else ("data", "model"))
        self.shape = dict(zip(self.axis_names, shape))


@pytest.mark.parametrize("name", ARCHS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_cover_all_leaves(name, multi_pod):
    cfg = get_config(name)
    mesh = FakeMesh(multi_pod)
    params = abstract_model(cfg)
    specs = param_specs(cfg, params, mesh)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim, (leaf.shape, spec)
        for ax in spec:
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            for a in axes:
                assert a in mesh.axis_names


@pytest.mark.parametrize("name", ARCHS)
def test_sanitized_specs_divisible(name):
    cfg = get_config(name)
    mesh = FakeMesh()
    params = abstract_model(cfg)
    specs = sanitize_specs(params, param_specs(cfg, params, mesh), mesh)
    for leaf, spec in zip(
            jax.tree_util.tree_leaves(params),
            jax.tree_util.tree_leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P))):
        for d, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert d % size == 0, (name, leaf.shape, spec)


@pytest.mark.parametrize("name", ARCHS)
def test_adapter_specs_client_axis(name):
    cfg = get_config(name)
    mesh = FakeMesh(multi_pod=True)
    ad = abstract_adapters(cfg, AdapterConfig(), n_clients=32)
    specs = adapter_specs(cfg, ad, mesh, client_axis=True)
    for leaf, spec in zip(
            jax.tree_util.tree_leaves(ad),
            jax.tree_util.tree_leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P))):
        assert spec[0] == ("pod", "data"), (leaf.shape, spec)


@pytest.mark.parametrize("name", ARCHS)
@pytest.mark.parametrize("shape_name",
                         ["train_4k", "prefill_32k", "decode_32k",
                          "long_500k"])
def test_entries_build_for_all_pairs(name, shape_name):
    """Entry construction (ShapeDtypeStructs + specs) for all 40 pairs.
    Does not compile — the dry-run does; this catches structural bugs
    fast."""
    cfg = get_config(name)
    shape = get_shape(shape_name)
    mesh = FakeMesh()
    entry = build_entry(cfg, shape, mesh, AdapterConfig())
    if skip_reason(cfg, shape):
        assert entry is None
        return
    # arg / spec trees must be congruent
    for args, specs in zip(entry.args, entry.in_specs):
        na = len(jax.tree_util.tree_leaves(args))
        ns = len(jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)))
        assert na == ns


def test_host_mesh_end_to_end_tiny():
    """A REAL lower+compile+execute of the federated train step on the 1×1
    host mesh with a tiny model — semantic check of the in-mesh runtime."""
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)
    mesh = make_host_mesh()
    from repro.configs.base import InputShape
    shape = InputShape("tiny_train", seq_len=32, global_batch=2, kind="train")
    entry = build_entry(cfg, shape, mesh, AdapterConfig(rank=4))
    lowered = lower_entry(entry, mesh)
    compiled = lowered.compile()
    # run it with real zeros
    args = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), entry.args)
    out = compiled(*args)
    adapters, opt_state, loss = out
    assert bool(jnp.isfinite(loss))


def test_fed_train_step_aggregates_A_in_mesh():
    """After one in-mesh round, FedSA leaves client A's identical and B's
    (zero-init but updated) potentially different."""
    cfg = reduced(get_config("stablelm-3b"), n_layers=2, d_model=64)
    mesh = make_host_mesh()
    from repro.configs.base import InputShape
    shape = InputShape("tiny_train", seq_len=16, global_batch=2, kind="train")
    entry = build_entry(cfg, shape, mesh, AdapterConfig(rank=4),
                        local_steps=2)
    lowered = lower_entry(entry, mesh)
    compiled = lowered.compile()
    params, adapters, opt_state, batch = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), entry.args)
    # real params + distinct per-client tokens
    from repro.models.transformer import init_model
    from repro.core.adapters import init_adapters
    from repro.core.aggregation import broadcast_clients
    params = init_model(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    single = init_adapters(jax.random.PRNGKey(1), cfg, AdapterConfig(rank=4))
    C = batch["tokens"].shape[0]
    adapters = broadcast_clients(single, C)
    batch = dict(batch)
    batch["tokens"] = jax.random.randint(jax.random.PRNGKey(2),
                                         batch["tokens"].shape, 0,
                                         cfg.vocab_size)
    batch["labels"] = jax.random.randint(jax.random.PRNGKey(3),
                                         batch["labels"].shape, 0,
                                         cfg.vocab_size)
    new_ad, _, loss = compiled(params, adapters, opt_state, batch)
    assert bool(jnp.isfinite(loss))
    # A leaves equal across clients (aggregated)
    A = new_ad["segments"][0]["attn"]["wq"]["A"]
    if C > 1:
        np.testing.assert_allclose(np.asarray(A[0]), np.asarray(A[-1]),
                                   rtol=1e-5)


# ---------------------------------------------------------------------------
# make_mesh factory (PR 9): general shapes, validated; production presets
# are thin wrappers over it
# ---------------------------------------------------------------------------

def test_make_mesh_builds_small_shapes():
    m = make_mesh((1, 1))
    assert m.axis_names == ("data", "model")
    assert dict(m.shape) == {"data": 1, "model": 1}
    m = make_mesh((1, 1), axes=("rows", "cols"))
    assert m.axis_names == ("rows", "cols")
    m = make_mesh((1, 1, 1))
    assert m.axis_names == ("pod", "data", "model")


@pytest.mark.parametrize("bad", [(), (0, 2), (2, -1)])
def test_make_mesh_rejects_bad_shapes(bad):
    with pytest.raises(ValueError, match="positive"):
        make_mesh(bad)


def test_make_mesh_rejects_rank_mismatch_and_unnamed_4d():
    with pytest.raises(ValueError, match="rank mismatch"):
        make_mesh((2, 2), axes=("data",))
    with pytest.raises(ValueError, match="pass axes="):
        make_mesh((1, 1, 1, 1))


def test_make_mesh_too_few_devices_names_the_flag():
    """The error must tell the user HOW to get the devices (the flag is
    useless unless exported before jax imports)."""
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_mesh((4096, 4096))


# ---------------------------------------------------------------------------
# Small-mesh divisibility/fallback: (2, 2) and (1, 4) — the serving
# meshes the multiproc tier runs on
# ---------------------------------------------------------------------------

SMALL = [(2, 2), (1, 4)]


def _axes_size(mesh, ax):
    axes = ax if isinstance(ax, tuple) else (ax,)
    return int(np.prod([mesh.shape[a] for a in axes]))


@pytest.mark.parametrize("shape", SMALL)
def test_param_specs_divisible_after_sanitize_on_small_mesh(shape):
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)
    mesh = FakeMesh(shape=shape)
    params = abstract_model(cfg)
    specs = sanitize_specs(params, param_specs(cfg, params, mesh), mesh)
    kept = 0
    for leaf, spec in zip(
            jax.tree_util.tree_leaves(params),
            jax.tree_util.tree_leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P))):
        for d, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            assert d % _axes_size(mesh, ax) == 0, (leaf.shape, spec)
            kept += 1
    # the fallback must not have replicated EVERYTHING: d_model=64
    # divides both small meshes, so tensor-parallel survives
    assert kept > 0


@pytest.mark.parametrize("shape", SMALL)
def test_adapter_specs_fallback_on_small_mesh(shape):
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)
    mesh = FakeMesh(shape=shape)
    ad = abstract_adapters(cfg, AdapterConfig(rank=4))
    specs = sanitize_specs(ad, adapter_specs(cfg, ad, mesh), mesh)
    for leaf, spec in zip(
            jax.tree_util.tree_leaves(ad),
            jax.tree_util.tree_leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P))):
        for d, ax in zip(leaf.shape, tuple(spec)):
            assert ax is None or d % _axes_size(mesh, ax) == 0, (
                leaf.shape, spec)


@pytest.mark.parametrize("shape", SMALL)
def test_paged_cache_specs_page_and_head_axes(shape):
    """Page axis over dp when n_pages divides, KV heads over "model"
    when they divide — and replicated fallback when not."""
    import functools
    from repro.models.transformer import init_paged_cache
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)
    mesh = FakeMesh(shape=shape)
    dsize, msize = mesh.shape["data"], mesh.shape["model"]
    for n_pages in (8, 9):                       # 9 never divides (2,2)
        cache = jax.eval_shape(functools.partial(
            init_paged_cache, cfg=cfg, n_pages=n_pages, page_size=4,
            dtype=jnp.float32))
        specs = paged_cache_specs(cfg, cache, mesh)
        for leaf, spec in zip(
                jax.tree_util.tree_leaves(cache),
                jax.tree_util.tree_leaves(
                    specs, is_leaf=lambda x: isinstance(x, P))):
            if leaf.ndim != 5:
                continue
            full = tuple(spec) + (None,) * (5 - len(spec))
            want_page = "data" if n_pages % dsize == 0 else None
            want_head = "model" if leaf.shape[3] % msize == 0 else None
            assert full[1] == want_page, (n_pages, shape, full)
            assert full[3] == want_head, (n_pages, shape, full)


@pytest.mark.parametrize("shape", SMALL)
def test_serving_table_specs_replicate_rows_shard_col_B(shape):
    """Slot tables never shard over "data"; col-parallel B tables carry
    "model" on their output dim when it divides."""
    from repro.core.adapters import init_adapters
    from repro.serving import AdapterRegistry
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)
    base = init_adapters(jax.random.PRNGKey(0), cfg,
                         AdapterConfig(mode="fedsa", rank=4))
    reg = AdapterRegistry({"adapters": base}, n_slots=2)
    mesh = FakeMesh(shape=shape)
    specs = serving_table_specs(reg.tables, reg.local_tree, mesh)
    saw_model = False
    for path, spec in jax.tree_util.tree_leaves_with_path(
            specs, is_leaf=lambda x: isinstance(x, P)):
        flat = [a for ax in tuple(spec) if ax is not None
                for a in (ax if isinstance(ax, tuple) else (ax,))]
        assert "data" not in flat, (path, spec)
        if "model" in flat:
            name = str(path[-1].key) if hasattr(path[-1], "key") else ""
            assert name == "B", (path, spec)
            assert tuple(spec)[-1] == "model"
            saw_model = True
    if mesh.shape["model"] > 1:
        assert saw_model, "no B table picked up the model axis"


@pytest.mark.parametrize("name", ["falcon-mamba-7b", "qwen3-32b"])
def test_cache_specs_structure(name):
    cfg = get_config(name)
    mesh = FakeMesh()
    from repro.models.transformer import init_cache
    import functools
    cache = jax.eval_shape(functools.partial(init_cache, cfg=cfg,
                                             batch_size=16, max_seq=128))
    specs = cache_specs(cfg, cache, mesh)
    n_c = len(jax.tree_util.tree_leaves(cache))
    n_s = len(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_c == n_s
