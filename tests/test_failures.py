"""Failure paths end to end: deterministic fault injection, the robust
federation round (rejection / clipping / rollback), serving degradation
(shed, deadline, degraded base-model slot), atomic checkpoints, and the
hardened train→serve bridge. See docs/robustness.md."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, FedConfig, get_config, reduced
from repro.core import federation
from repro.core.adapters import init_adapters
from repro.core.aggregation import _trimmed_mean, aggregate
from repro.core.strategies import LOCAL, leaf_role
from repro.data.synthetic import make_classification_task
from repro.failures import (FaultInjector, FaultPlan, PagePressure,
                            default_plan)
from repro.models.transformer import decode_step, init_model, prefill
from repro.obs import TraceLog
from repro.serving import AdapterRegistry, ServingConfig, ServingEngine
from repro.serving.demo import synthetic_clients

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# FaultPlan / FaultInjector determinism
# ---------------------------------------------------------------------------

def test_fault_plan_deterministic_replay():
    """The same plan replayed in a DIFFERENT query order (and with
    unrelated queries interleaved) yields identical per-key decisions —
    the property every postmortem and the chaos CI job rest on."""
    plan = default_plan(seed=3)
    a = FaultInjector(plan)
    b = FaultInjector(plan)
    keys = [(r, c) for r in range(4) for c in range(8)]
    got_a = {k: a.client_fate(*k)[0] for k in keys}
    for v in range(6):                   # unrelated draws must not shift
        b.drops_publish(v)               # the dropout stream
    got_b = {k: b.client_fate(*k)[0] for k in reversed(keys)}
    assert got_a == got_b
    assert [a.corrupts(1, c) for c in range(8)] == \
           [b.corrupts(1, c) for c in range(8)]
    # a different seed is a different timeline
    c = FaultInjector(default_plan(seed=4))
    assert any(got_a[k] != c.client_fate(*k)[0] for k in keys) or \
        [a.corrupts(1, i) for i in range(8)] != \
        [c.corrupts(1, i) for i in range(8)]


def test_fault_injector_records_and_traces():
    trace = TraceLog(validate=True)
    inj = FaultInjector(FaultPlan(seed=0, dropout_rate=1.0,
                                  retry_success_rate=0.0), trace=trace)
    dropped, _ = inj.client_fate(0, 0)
    assert dropped
    assert inj.count("dropout") == 1
    assert trace.by_type("fault_injected")[0]["kind"] == "dropout"
    # rate-1.0 plans fire always; rate-0.0 plans never
    calm = FaultInjector(FaultPlan(seed=0))
    assert not any(calm.client_fate(r, c)[0]
                   for r in range(3) for c in range(4))


def test_fault_plan_validates_rates():
    with pytest.raises(ValueError):
        FaultPlan(dropout_rate=1.5)
    with pytest.raises(ValueError):
        FaultPlan(corrupt_kind="garbage")


# ---------------------------------------------------------------------------
# Aggregation validation primitives
# ---------------------------------------------------------------------------

def test_trimmed_mean_drops_extremes():
    x = jnp.asarray([[1.0], [2.0], [3.0], [100.0]])
    valid = jnp.ones((4,))
    # trim=0.25 drops one rank at each end → mean(2, 3)
    assert np.allclose(_trimmed_mean(x, valid, trim=0.25), 2.5)
    # trim=0 is the plain mean over the valid clients
    assert np.allclose(_trimmed_mean(x, valid, trim=0.0), x.mean(0))
    # invalid clients are pushed past every valid rank: excluding the
    # outlier client changes nothing else
    assert np.allclose(
        _trimmed_mean(x, jnp.asarray([1.0, 1.0, 1.0, 0.0]), trim=0.0),
        2.0)


def test_aggregate_excluded_nan_does_not_poison_mean():
    """participation=0 for a NaN client must fully exclude it — the
    0-weight × NaN = NaN tensordot pitfall."""
    adapters = {"adapters": {"blk": {"attn": {
        "A": jnp.stack([jnp.ones((2, 2)), jnp.full((2, 2), jnp.nan)]),
        "B": jnp.zeros((2, 2, 2))}}}}
    part = jnp.asarray([1.0, 0.0])
    out = aggregate(adapters, "fedsa", participation=part,
                    receive=jnp.ones((2,)))
    A = out["adapters"]["blk"]["attn"]["A"]
    assert np.isfinite(np.asarray(A)).all()
    assert np.allclose(A, 1.0)           # both clients receive the mean


# ---------------------------------------------------------------------------
# Robust federation rounds
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fed_setup():
    cfg = reduced(get_config("roberta-large"), n_layers=2, d_model=64)
    clients, _ = make_classification_task(
        n_clients=3, n_classes=4, vocab=cfg.vocab_size, seq=16,
        n_train=240, n_test=60, alpha=0.5, seed=0)
    return cfg, clients


def build_system(cfg, seed=0):
    fed = FedConfig(n_clients=3, local_steps=2)
    acfg = AdapterConfig(mode="fedsa", rank=4)
    return federation.build(jax.random.PRNGKey(seed), cfg, acfg, fed,
                            task="classification", n_classes=4, lr=5e-2)


def shared_leaves(tr, mode="fedsa"):
    flat = jax.tree_util.tree_flatten_with_path(tr)[0]
    return [np.asarray(leaf) for path, leaf in flat
            if leaf_role(path, mode) != LOCAL]


def test_corrupted_update_rejected_round_trip(fed_setup):
    """NaN client updates are rejected at the validation gate: training
    survives with finite losses, zero rollbacks, and the rejected
    clients still RECEIVE the clean aggregate (heal path)."""
    cfg, clients = fed_setup
    sys = build_system(cfg)
    plan = FaultPlan(seed=2, corrupt_rate=0.5, corrupt_kind="nan")
    trace = TraceLog(validate=True)
    faults = FaultInjector(plan, trace=trace)
    hist = federation.run_rounds(sys, clients, rounds=4, batch_size=16,
                                 seed=1, faults=faults, trace=trace)
    n_rej = sum(len(r) for r in hist["rejected"])
    assert np.isfinite(hist["loss"]).all()
    assert hist["rollbacks"] == 0
    assert n_rej >= 1
    assert n_rej == faults.count("corrupt")
    assert len(trace.by_type("update_rejected")) == n_rej
    for leaf in shared_leaves(sys.trainables):
        assert np.isfinite(leaf).all()
        # post-aggregation: every client (incl. rejected) holds the
        # same shared Ā
        assert np.allclose(leaf, leaf[0])


def test_rollback_heals_bad_aggregate(fed_setup):
    """With the validation gate off, a NaN update reaches the mean; the
    post-aggregate check must roll the shared leaves back to last-good
    and count it — weights stay finite for serving."""
    cfg, clients = fed_setup
    sys = build_system(cfg)
    plan = FaultPlan(seed=2, corrupt_rate=0.5, corrupt_kind="nan")
    robust = federation.RobustConfig(reject_nonfinite=False)
    trace = TraceLog(validate=True)
    hist = federation.run_rounds(sys, clients, rounds=4, batch_size=16,
                                 seed=1, faults=FaultInjector(plan),
                                 robust=robust, trace=trace)
    assert hist["rollbacks"] >= 1
    assert len(trace.by_type("rollback")) == hist["rollbacks"]
    for leaf in shared_leaves(sys.trainables):
        assert np.isfinite(leaf).all()


def test_full_dropout_round_keeps_state(fed_setup):
    """Every client dropped every round → no update ever lands: the
    trainables (shared AND local) are bit-identical to the start."""
    cfg, clients = fed_setup
    sys = build_system(cfg)
    before = jax.tree_util.tree_map(np.asarray, sys.trainables)
    plan = FaultPlan(seed=0, dropout_rate=1.0, retry_success_rate=0.0)
    hist = federation.run_rounds(sys, clients, rounds=2, batch_size=16,
                                 seed=1, faults=FaultInjector(plan))
    assert hist["dropped"] == [[0, 1, 2], [0, 1, 2]]
    after = jax.tree_util.tree_map(np.asarray, sys.trainables)
    for a, b in zip(jax.tree_util.tree_leaves(before),
                    jax.tree_util.tree_leaves(after)):
        assert np.array_equal(a, b)


def test_faulted_history_replays_bit_exact(fed_setup):
    """Same plan + same workload → identical fault timeline AND
    identical training history (the deterministic-replay acceptance)."""
    cfg, clients = fed_setup
    runs = []
    for _ in range(2):
        sys = build_system(cfg)
        faults = FaultInjector(default_plan(seed=1))
        hist = federation.run_rounds(sys, clients, rounds=3,
                                     batch_size=16, seed=1, faults=faults)
        runs.append((hist, faults.decisions))
    (h0, d0), (h1, d1) = runs
    assert d0 == d1
    assert h0["dropped"] == h1["dropped"]
    assert h0["rejected"] == h1["rejected"]
    assert np.allclose(h0["loss"], h1["loss"])


# ---------------------------------------------------------------------------
# Serving degradation
# ---------------------------------------------------------------------------

def tiny_cfg():
    return reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)


@pytest.fixture(scope="module")
def serve_setup():
    cfg = tiny_cfg()
    acfg = AdapterConfig(mode="fedsa", rank=4)
    params = init_model(KEY, cfg, jnp.float32)
    template = {"adapters": init_adapters(KEY, cfg, acfg)}
    trees = synthetic_clients(template, 4, seed=50, scale=0.05)
    return cfg, acfg, params, template, trees


def make_engine(serve_setup, *, n_slots=2, n_clients=4, trace=None, **kw):
    cfg, acfg, params, template, trees = serve_setup
    reg = AdapterRegistry(template, n_slots=n_slots)
    for i, t in enumerate(trees[:n_clients]):
        reg.ingest(i, t)
    return ServingEngine(cfg, params, acfg, reg,
                         ServingConfig(max_batch=2, max_seq=32, **kw),
                         trace=trace)


def test_queue_bound_sheds_excess(serve_setup):
    trace = TraceLog(validate=True)
    engine = make_engine(serve_setup, max_queue=1, trace=trace)
    prompt = np.arange(4) % 7
    rids = [engine.submit(i % 4, prompt, max_new_tokens=4)
            for i in range(4)]
    # one queued, the rest shed with an explicit event
    assert rids[0] is not None and rids[1:] == [None, None, None]
    assert engine.scheduler.shed == 3
    shed = trace.by_type("request_shed")
    assert len(shed) == 3
    assert all(e["reason"] == "queue_full" for e in shed)
    rep = engine.run()
    assert rep["shed_requests"] == 3
    # accounting identity: submitted == finished + shed
    assert engine.scheduler._next_rid == len(engine.finished) + 3


def test_scheduler_recovers_after_pool_pressure(serve_setup):
    """PagePressure holds every free page → admission stalls with
    pool_exhausted (requests queue, nothing lost); release → the queue
    drains on its own and every request retires."""
    trace = TraceLog(validate=True)
    engine = make_engine(serve_setup, trace=trace)
    pressure = PagePressure(engine.pool, 1.0)
    held = pressure.apply()
    assert held == engine.pool.capacity
    for i in range(3):
        engine.submit(i % 4, np.arange(6) % 7, max_new_tokens=4)
    for _ in range(4):
        engine.step()
    assert len(engine.finished) == 0            # stuck, not lost
    assert len(trace.by_type("pool_exhausted")) >= 1
    assert len(engine.scheduler.queue) == 3
    pressure.release()
    rep = engine.run()
    assert rep["requests"] == 3                 # full recovery
    assert engine.scheduler.shed == 0


def test_unknown_client_degrades_to_base_model(serve_setup):
    """A never-ingested tenant serves the base model (degraded=True off
    the registry's zero slot) instead of raising — and its tokens match
    a reference decode with a zeroed LoRA delta."""
    cfg, acfg, params, template, trees = serve_setup
    trace = TraceLog(validate=True)
    engine = make_engine(serve_setup, degrade_after_s=5.0, trace=trace)
    prompt = (np.arange(9) * 3) % 11
    rid = engine.submit(99, prompt, max_new_tokens=6)
    rep = engine.run()
    rec = engine.finished[rid]
    assert rec["degraded"]
    assert rep["degraded_served"] == 1
    ev = trace.by_type("degraded_serve")
    assert len(ev) == 1 and ev[0]["reason"] == "unknown_client"
    # reference: the base model IS a zero LoRA delta (B ≡ 0)
    zero_b = jax.tree_util.tree_map_with_path(
        lambda p, x: jnp.zeros_like(x) if leaf_role(p, acfg.mode) == LOCAL
        else x, template)
    ad = zero_b["adapters"]
    toks = jnp.asarray(np.asarray(prompt)[None].astype(np.int32))
    logits, cache, _ = prefill(cfg, params, ad, acfg, toks, 32,
                               cache_dtype=jnp.float32)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    want = [int(tok[0, 0])]
    for s in range(5):
        pos = jnp.full((1,), len(prompt) + s, jnp.int32)
        logits, cache = decode_step(cfg, params, ad, acfg, tok, pos, cache)
        tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        want.append(int(tok[0, 0]))
    assert list(rec["tokens"]) == want


def test_all_pinned_without_degradation_still_waits(serve_setup):
    """Legacy semantics preserved: degrade_after_s=None keeps the
    stay-queued behavior on an all-pinned registry (and raises on an
    unknown client)."""
    engine = make_engine(serve_setup, n_slots=1)
    engine.submit(0, np.arange(6) % 7, max_new_tokens=4)
    engine.submit(1, np.arange(6) % 7, max_new_tokens=4)
    rep = engine.run()                           # sequential slot reuse
    assert rep["requests"] == 2
    assert engine.scheduler.degraded_admits == 0
    with pytest.raises(KeyError):
        engine.submit(99, np.arange(4) % 7, max_new_tokens=2)
        engine.run()


def test_all_pinned_degrades_after_patience(serve_setup):
    """n_slots=1, two tenants in flight: the second can't pin a slot —
    after degrade_after_s it serves base-model instead of starving."""
    trace = TraceLog(validate=True)
    engine = make_engine(serve_setup, n_slots=1, degrade_after_s=0.0,
                         trace=trace)
    engine.submit(0, np.arange(12) % 7, max_new_tokens=8)
    engine.step()                                # client 0 pins slot 0
    engine.submit(1, np.arange(6) % 7, max_new_tokens=4)
    rep = engine.run()
    assert rep["requests"] == 2
    degraded = [r for r in engine.finished.values() if r["degraded"]]
    assert len(degraded) == 1
    ev = trace.by_type("degraded_serve")
    assert len(ev) == 1 and ev[0]["reason"] == "all_pinned"


def test_request_deadline_retires_overdue_row(serve_setup):
    """An admitted row past its submit→retire deadline is retired
    cleanly (partial tokens, deadline_exceeded event) — the row, pin
    and pages come back to the queue."""
    trace = TraceLog(validate=True)
    engine = make_engine(serve_setup, trace=trace)
    rid = engine.submit(0, np.arange(6) % 7, max_new_tokens=16,
                        deadline_s=1e9)
    engine.step()                                # admit + prefill
    seq = next(iter(engine.scheduler.active.values()))
    assert seq.request.rid == rid
    seq.request.deadline_s = 1e-9                # now overdue mid-decode
    rep = engine.run()
    rec = engine.finished[rid]
    assert rec["deadline_exceeded"]
    assert len(rec["tokens"]) < 16
    assert rep["deadline_retired"] == 1
    assert len(trace.by_type("deadline_exceeded")) == 1
    # the engine is healthy afterwards: next request serves fully
    rid2 = engine.submit(1, np.arange(4) % 7, max_new_tokens=4)
    engine.run()
    assert len(engine.finished[rid2]["tokens"]) == 4


def test_overdue_queued_request_is_shed(serve_setup):
    trace = TraceLog(validate=True)
    engine = make_engine(serve_setup, trace=trace)
    engine.submit(0, np.arange(4) % 7, max_new_tokens=4, deadline_s=0.0)
    rep = engine.run()
    assert rep["requests"] == 0 and engine.scheduler.shed == 1
    ev = trace.by_type("request_shed")
    assert len(ev) == 1 and ev[0]["reason"] == "deadline"


# ---------------------------------------------------------------------------
# Registry publish validation + bounded flip retry
# ---------------------------------------------------------------------------

def versioned_registry(serve_setup, **kw):
    _, _, _, template, trees = serve_setup
    reg = AdapterRegistry(template, n_slots=2, versioned=True, **kw)
    for i, t in enumerate(trees):
        reg.ingest(i, t)
    return reg


def nan_tree(tree, mode, role=LOCAL):
    return jax.tree_util.tree_map_with_path(
        lambda p, x: jnp.full_like(x, jnp.nan)
        if leaf_role(p, mode) == role else x, tree)


def test_publish_rejects_nonfinite(serve_setup):
    _, acfg, _, _, trees = serve_setup
    trace = TraceLog(validate=True)
    reg = versioned_registry(serve_setup, validate_publish=True)
    reg.trace = trace
    # poisoned shared Ā → whole publish refused, version unchanged
    from repro.core.strategies import SHARED
    bad_shared = nan_tree(trees[0], acfg.mode, role=SHARED)
    assert reg.publish(1, {0: bad_shared}, shared_from=bad_shared) is False
    assert reg.version == 0 and reg.publish_rejects == 1
    assert trace.by_type("rollback")[0]["reason"] == "nonfinite_shared"
    # one poisoned B_i → only that client's stage dropped
    flipped = reg.publish(1, {0: nan_tree(trees[0], acfg.mode),
                              1: trees[1]})
    assert flipped and reg.version == 1
    assert reg._client_ver[1] == 1 and reg._client_ver[0] == 0
    rej = trace.by_type("update_rejected")
    assert len(rej) == 1 and rej[0]["client"] == 0


def test_flip_patience_drops_stuck_publish(serve_setup):
    _, _, _, _, trees = serve_setup
    trace = TraceLog(validate=True)
    reg = versioned_registry(serve_setup, flip_patience=3)
    reg.trace = trace
    buf = reg.retain_buffer()                    # a long-lived row admitted
    assert reg.publish(1, {0: trees[0]}) is True  # other buffer was free
    # the row still reads the now-inactive buffer → round 2 can't flip
    assert reg.publish(2, {1: trees[1]}) is False
    for _ in range(2):
        assert reg.try_flip() is False
    # patience exhausted: the stage is dropped, last-good keeps serving
    assert reg.stats["pending_version"] is None
    assert reg.flip_timeouts == 1 and reg.version == 1
    assert any(e["reason"] == "flip_timeout"
               for e in trace.by_type("rollback"))
    reg.release_buffer(buf)
    # the NEXT publish is fresh and commits normally
    assert reg.publish(3, {1: trees[1]}) is True
    assert reg.version == 3


# ---------------------------------------------------------------------------
# Atomic checkpoints + hardened bridge + bench gate errors
# ---------------------------------------------------------------------------

def test_atomic_checkpoint_survives_crash(tmp_path, monkeypatch):
    from repro.checkpoint import npz as ckpt
    tree = {"w": jnp.ones((3, 2)), "b": jnp.zeros((2,))}
    path = str(tmp_path / "state.npz")
    ckpt.save_pytree(path, tree)
    good = open(path, "rb").read()

    calls = {"n": 0}
    real = np.savez

    def crashy(f, **arrays):
        real(f, **arrays)
        raise OSError("disk full mid-save")

    monkeypatch.setattr(np, "savez", crashy)
    with pytest.raises(OSError):
        ckpt.save_pytree(path, {"w": jnp.full((3, 2), 9.0),
                                "b": jnp.ones((2,))})
    monkeypatch.undo()
    # the old checkpoint is untouched and no temp litter remains
    assert open(path, "rb").read() == good
    assert os.listdir(tmp_path) == ["state.npz"]
    restored = ckpt.load_pytree(path, tree)
    assert np.allclose(restored["w"], 1.0)


def test_trainer_thread_death_reraised(monkeypatch):
    """A trainer-thread exception must surface in the caller, not park
    the serving loop forever."""
    from repro.serving import refresh

    def boom(*a, **kw):
        raise ValueError("synthetic trainer crash")

    monkeypatch.setattr(federation, "run_rounds", boom)
    cfg = tiny_cfg()
    acfg = AdapterConfig(mode="fedsa", rank=4)
    fed = FedConfig(n_clients=2, local_steps=1)
    with pytest.raises(RuntimeError, match="trainer thread died") as exc:
        refresh.train_and_serve(cfg, acfg, fed, rounds=1, requests=2,
                                n_slots=2, max_new_tokens=2)
    assert isinstance(exc.value.__cause__, ValueError)


def test_bench_gate_names_missing_and_bad_records(tmp_path, capsys):
    import sys
    sys.path.insert(0, str(tmp_path.parent))
    from benchmarks import bench_gate
    rc = bench_gate.main(["--fresh", str(tmp_path / "nope.json")])
    out = capsys.readouterr().out
    assert rc == 1
    assert "cannot read fresh record" in out and "nope.json" in out
    bad = tmp_path / "bad.json"
    bad.write_text('{"bench": "serving_chaos", not json')
    rc = bench_gate.main(["--fresh", str(bad)])
    out = capsys.readouterr().out
    assert rc == 1 and "not valid JSON" in out
    fresh = tmp_path / "fresh.json"
    fresh.write_text('{"bench": "serving_chaos", '
                     '"faulted_decode_ratio": 1.0, "config": {}}')
    rc = bench_gate.main(["--fresh", str(fresh),
                          "--baseline", str(tmp_path / "gone.json")])
    out = capsys.readouterr().out
    assert rc == 1
    assert "cannot read baseline record" in out
    assert "faulted_decode_ratio" in out       # names the expected spec
    assert "regenerate" in out
