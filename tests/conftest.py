"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only launch/dryrun.py forces 512 placeholder devices."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import AdapterConfig, get_config, reduced


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def tiny(name, **kw):
    return reduced(get_config(name), **kw)


@pytest.fixture(scope="session")
def acfg():
    return AdapterConfig(rank=4)


def tree_all_finite(tree):
    return all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree_util.tree_leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))
