"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only launch/dryrun.py forces 512 placeholder devices."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import AdapterConfig, get_config, reduced

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # bare CI env — property-based tests skip, rest run
    HAVE_HYPOTHESIS = False

    def given(*a, **k):
        return lambda f: pytest.mark.optional_deps(
            pytest.mark.skip(reason="hypothesis not installed")(f))
    settings = given

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None
    st = _AnyStrategy()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "optional_deps: needs an optional dependency (hypothesis); "
        "skipped rather than errored on bare environments")


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def tiny(name, **kw):
    return reduced(get_config(name), **kw)


@pytest.fixture(scope="session")
def acfg():
    return AdapterConfig(rank=4)


def tree_all_finite(tree):
    return all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree_util.tree_leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))
