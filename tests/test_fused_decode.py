"""Fused on-device multi-tick decode (``decode_backend="fused"``): exact
token parity with the per-tick engine across layouts and backends,
per-row budget/EOS masking, and the fused-phase page-window planning."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import init_model
from repro.serving import AdapterRegistry, ServingConfig, ServingEngine
from repro.serving.demo import mixed_fleet, synthetic_clients

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)
    acfg = AdapterConfig(mode="fedsa", rank=4)
    params = init_model(KEY, cfg, jnp.float32)
    base = init_adapters(KEY, cfg, acfg)
    trees = [t["adapters"] for t in
             synthetic_clients({"adapters": base}, 5, seed=50, scale=0.05)]
    return cfg, acfg, params, base, trees


def make_engine(setup, **kw):
    cfg, acfg, params, base, trees = setup
    reg = AdapterRegistry({"adapters": base}, n_slots=kw.pop("n_slots", 2))
    for i, t in enumerate(trees):
        reg.ingest(i, {"adapters": t})
    return ServingEngine(cfg, params, acfg, reg, ServingConfig(**kw))


def serve(eng, prompts, *, n_clients=3, new_tokens=7):
    for i, p in enumerate(prompts):
        eng.submit(i % n_clients, p, max_new_tokens=new_tokens)
    rep = eng.run()
    return rep, {r: eng.finished[r]["tokens"].tolist() for r in eng.finished}


HETERO = [6, 13, 4, 9, 17, 6, 11, 3]


def hetero_prompts(cfg, lens=HETERO, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(n)) for n in lens]


# ---------------------------------------------------------------------------
# token parity: fused scan vs per-tick, across layouts / tick counts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["paged", "dense"])
@pytest.mark.parametrize("ticks", [1, 4, 8])
def test_fused_vs_pertick_token_parity(setup, layout, ticks):
    """The tentpole invariant: moving the decode loop on-device (budget
    masking, in-loop page commit, scan-hoisted gather) must not change a
    single token — heterogeneous prompts, eviction churn, row refill
    mid-stream included."""
    cfg = setup[0]
    prompts = hetero_prompts(cfg)
    _, want = serve(make_engine(setup, max_batch=2, max_seq=32,
                                kv_layout=layout, page_size=8), prompts)
    rep, got = serve(make_engine(setup, max_batch=2, max_seq=32,
                                 kv_layout=layout, page_size=8,
                                 decode_backend="fused",
                                 decode_ticks=ticks), prompts)
    assert got == want
    assert rep["decode_backend"] == "fused"
    assert rep["requests"] == len(prompts)


def test_fused_pallas_attn_parity(setup):
    """attn_backend="pallas" inside the fused scan: the kernel's
    in-kernel K/V append replaces the per-layer pool pre-scatter —
    tokens must not change."""
    cfg = setup[0]
    prompts = hetero_prompts(cfg, lens=[6, 13, 4, 9])
    _, want = serve(make_engine(setup, max_batch=2, max_seq=32,
                                kv_layout="paged", page_size=8), prompts)
    _, got = serve(make_engine(setup, max_batch=2, max_seq=32,
                               kv_layout="paged", page_size=8,
                               attn_backend="pallas",
                               decode_backend="fused", decode_ticks=4),
                   prompts)
    assert got == want


def test_fused_bgmv_lora_parity(setup):
    """The bgmv gather works inside the scan: slot/buf ids are
    loop-invariant between syncs, the gather hoists out of the ticks."""
    cfg = setup[0]
    prompts = hetero_prompts(cfg, lens=[6, 13, 4, 9])
    _, want = serve(make_engine(setup, max_batch=2, max_seq=32,
                                kv_layout="paged", page_size=8), prompts)
    for layout in ("paged", "dense"):
        _, got = serve(make_engine(setup, max_batch=2, max_seq=32,
                                   kv_layout=layout, page_size=8,
                                   lora_backend="bgmv",
                                   decode_backend="fused", decode_ticks=4),
                       prompts)
        assert got == want, layout


def test_fused_sgmv_mixed_fleet_parity(setup):
    """The sgmv gather (per-row A_i) works inside the scan: a mixed
    FedSA+FedIT fleet decodes fused, token-identical to the per-tick
    jnp engine."""
    cfg, acfg, params, base, _ = setup
    template = {"adapters": base}
    trees, modes = mixed_fleet(template, 4, seed=21, scale=0.05)

    def run(lora_backend, **kw):
        reg = AdapterRegistry(template, n_slots=3, mode="fedit")
        for i, t in enumerate(trees):
            reg.ingest(i, t)
        eng = ServingEngine(cfg, params, acfg, reg,
                            ServingConfig(max_batch=3, max_seq=16,
                                          lora_backend=lora_backend, **kw))
        rng = np.random.default_rng(7)
        prompts = [rng.integers(0, cfg.vocab_size, 6) for _ in range(5)]
        for i, p in enumerate(prompts):
            eng.submit(i % len(trees), p, max_new_tokens=5)
        eng.run()
        return {r: eng.finished[r]["tokens"].tolist() for r in eng.finished}

    want = run("jnp")
    got = run("sgmv", decode_backend="fused", decode_ticks=4)
    assert got == want


# ---------------------------------------------------------------------------
# per-row EOS / budget masking
# ---------------------------------------------------------------------------

def test_eos_truncates_identically_on_both_backends(setup):
    """A row emitting eos_id stops mid-window on device (budget zeroed
    after the token counts) exactly as the per-tick engine stops at its
    sync — and other rows in the batch are unaffected."""
    cfg = setup[0]
    prompts = hetero_prompts(cfg)
    _, base_toks = serve(make_engine(setup, max_batch=2, max_seq=32,
                                     kv_layout="paged", page_size=8),
                         prompts)
    eos = base_toks[1][2]                # a token request 1 emits mid-run
    _, want = serve(make_engine(setup, max_batch=2, max_seq=32,
                                kv_layout="paged", page_size=8,
                                eos_id=eos), prompts)
    assert want[1][-1] == eos and len(want[1]) < len(base_toks[1])
    for layout in ("paged", "dense"):
        rep, got = serve(make_engine(setup, max_batch=2, max_seq=32,
                                     kv_layout=layout, page_size=8,
                                     eos_id=eos, decode_backend="fused",
                                     decode_ticks=8), prompts)
        assert got == want, layout
        # pad emissions of the finished row are never booked
        assert rep["decode_tokens"] == sum(len(v) for v in got.values()) \
            - len(got)


def test_fused_budgets_never_overrun(setup):
    """max_new_tokens is enforced per row inside the window: rows with
    different budgets share one scan and none overruns between syncs."""
    cfg = setup[0]
    rng = np.random.default_rng(5)
    eng = make_engine(setup, max_batch=4, max_seq=32, kv_layout="paged",
                      page_size=8, decode_backend="fused", decode_ticks=8)
    budgets = [2, 9, 5, 16]
    for i, b in enumerate(budgets):
        eng.submit(i % 3, rng.integers(0, cfg.vocab_size, 6),
                   max_new_tokens=b)
    eng.run()
    for rid, b in enumerate(budgets):
        assert len(eng.finished[rid]["tokens"]) == b, rid


# ---------------------------------------------------------------------------
# fused-phase planning
# ---------------------------------------------------------------------------

def test_plan_ticks_pow2_floor_and_budget_clamp(setup):
    eng = make_engine(setup, max_batch=2, max_seq=32, kv_layout="paged",
                      page_size=8, decode_backend="fused", decode_ticks=8)
    for budgets, want in (([5, 1], 4), ([8, 8], 8), ([1, 1], 1),
                          ([3, 0], 2), ([16, 2], 8)):
        got = eng._plan_ticks(np.asarray(budgets, np.int32))
        assert got == want, (budgets, got)


def test_plan_ticks_shrinks_on_page_spill(setup):
    """Spill → shrink T: if a row's reservation cannot cover its tick
    window (forced here by shrinking the reservation under the
    scheduler), the batch's T halves until every window fits."""
    cfg = setup[0]
    eng = make_engine(setup, max_batch=2, max_seq=32, kv_layout="paged",
                      page_size=8, decode_backend="fused", decode_ticks=8)
    eng.submit(0, np.zeros(6, np.int32), max_new_tokens=8)
    eng.scheduler.admit(eng.registry)
    seq = next(iter(eng.scheduler.active.values()))
    assert eng._plan_ticks(np.asarray([seq.budget], np.int32)) == 8
    seq.pages = seq.pages[:1]            # doctor: reservation of 1 page
    assert eng._plan_ticks(np.asarray([seq.budget], np.int32)) < 8
    assert eng.fused_tick_shrinks > 0
