"""Sharded serving test tier (PR 9) — run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

The tier-1 CI leg ``tier1-multiproc`` runs exactly this file (plus the
mesh/rules unit tests) with 8 forced host devices, so every sharded
path executes through real XLA SPMD partitioning on CPU:

  * token parity — the (N, 1) data-sharded engine emits BIT-IDENTICAL
    tokens to the single-device engine on the same workload, across
    paged/dense layouts and per-tick/fused decode. Parity meshes keep
    the model axis at 1: row sharding only splits independent batch
    rows, while a >1 "model" axis would psum row-parallel partials in a
    different reduction order (bit-equality is not a TP guarantee),
  * collective flip — a publish that lands mid-stream flips on every
    shard on the same tick (the engine's post-commit pmin/pmax
    all-reduce of the version asserts it), with token parity preserved
    across the flip,
  * degraded serving — the base-model zero-slot path runs on a mesh,
  * spec compliance — after real jitted steps the engine's cache,
    params, and registry tables still carry the intended shardings on
    (2, 2) and (1, 4) meshes (page axis + decode rows over "data",
    tensor-parallel dims and col-parallel B tables over "model", slot
    tables replicated over "data").

The forced-device flag must be set BEFORE jax is imported, so this file
never sets it itself — it skips (rather than fakes a pass) when the
host exposes too few devices.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import init_model
from repro.serving import AdapterRegistry, ServingConfig, ServingEngine
from repro.serving.demo import synthetic_clients
from repro.serving.sharded import (collective_flip_check, data_size,
                                   serving_mesh)

N_DEV = 8
pytestmark = pytest.mark.skipif(
    len(jax.devices()) < N_DEV,
    reason=f"needs {N_DEV} devices — run under "
           f"XLA_FLAGS=--xla_force_host_platform_device_count={N_DEV} "
           "(set before jax imports)")

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)
    acfg = AdapterConfig(mode="fedsa", rank=4)
    params = init_model(KEY, cfg, jnp.float32)
    base = init_adapters(KEY, cfg, acfg)
    trees = [t["adapters"] for t in
             synthetic_clients({"adapters": base}, 4, seed=50, scale=0.05)]
    return cfg, acfg, params, base, trees


def make_registry(base, trees, n_slots=4, versioned=False):
    reg = AdapterRegistry({"adapters": base}, n_slots=n_slots,
                          versioned=versioned)
    for i, t in enumerate(trees):
        reg.ingest(i, {"adapters": t})
    return reg


def make_engine(setup, mesh_shape=None, versioned=False, **knobs):
    cfg, acfg, params, base, trees = setup
    config = ServingConfig(max_batch=4, max_seq=16, page_size=8,
                           shard_serving=mesh_shape is not None,
                           mesh_shape=mesh_shape, **knobs)
    return ServingEngine(cfg, params, acfg,
                         make_registry(base, trees, versioned=versioned),
                         config)


def run_tokens(eng, cfg, n=6, new_tokens=6):
    rng = np.random.default_rng(5)
    for i, p in enumerate(rng.integers(0, cfg.vocab_size, (n, 5))):
        eng.submit(i % 3, p, max_new_tokens=new_tokens)
    eng.run()
    return {r: eng.finished[r]["tokens"].tolist() for r in eng.finished}


# ---------------------------------------------------------------------------
# Token parity: sharded == single-device, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_layout", ["paged", "dense"])
@pytest.mark.parametrize("decode_backend", ["per-tick", "fused"])
def test_sharded_token_parity(setup, kv_layout, decode_backend):
    cfg = setup[0]
    knobs = dict(kv_layout=kv_layout, decode_backend=decode_backend,
                 decode_ticks=4)
    single = run_tokens(make_engine(setup, **knobs), cfg)
    sharded_eng = make_engine(setup, mesh_shape=(4, 1), **knobs)
    sharded = run_tokens(sharded_eng, cfg)
    assert sharded == single, (
        f"{kv_layout}/{decode_backend}: sharded tokens diverged from the "
        "single-device engine")
    rep = sharded_eng.report()
    assert rep["sharded"] and rep["mesh_shape"] == (4, 1)


def test_sharded_report_keys(setup):
    eng = make_engine(setup, mesh_shape=(4, 1), kv_layout="paged")
    run_tokens(eng, setup[0])
    rep = eng.report()
    assert rep["collective_flips"] == 0          # unversioned: no flips
    assert rep["cross_shard_allocs"] >= 0
    plain = make_engine(setup).report()
    assert plain["sharded"] is False and plain["mesh_shape"] is None


# ---------------------------------------------------------------------------
# Collective flip: mid-publish parity + the all-reduce version check
# ---------------------------------------------------------------------------

def drive_with_mid_publish(eng, cfg, trees):
    """Submit, run two ticks, publish round 1 mid-stream, drain."""
    rng = np.random.default_rng(9)
    for i, p in enumerate(rng.integers(0, cfg.vocab_size, (4, 5))):
        eng.submit(i % 3, p, max_new_tokens=8)
    eng.step()
    eng.step()
    eng.registry.publish(1, {1: {"adapters": trees[1]}})
    eng.run()
    return {r: eng.finished[r]["tokens"].tolist() for r in eng.finished}


def test_collective_flip_mid_publish_parity(setup):
    cfg, _, _, _, trees = setup
    single = drive_with_mid_publish(
        make_engine(setup, versioned=True, kv_layout="paged"), cfg, trees)
    eng = make_engine(setup, mesh_shape=(4, 1), versioned=True,
                      kv_layout="paged")
    sharded = drive_with_mid_publish(eng, cfg, trees)
    assert sharded == single, "tokens diverged across a mid-stream flip"
    assert eng.registry.version == 1 and eng.registry.flips == 1
    # the flip was verified by the mesh-wide all-reduce exactly once
    assert eng.collective_flips == 1


def test_collective_flip_check_primitive():
    """The all-reduce itself: every device of a 2-axis mesh agrees on
    the version (pmin == pmax == version)."""
    mesh = serving_mesh((4, 2))
    assert data_size(mesh) == 4
    for v in (0, 3, 2**20):
        assert collective_flip_check(mesh, v) == (v, v)


def test_torn_flip_would_raise(setup):
    """The engine raises on lo != hi == version disagreement. A real
    torn flip cannot be produced from the single-controller engine (the
    guarantee under test), so exercise the guard directly."""
    eng = make_engine(setup, mesh_shape=(2, 1), versioned=True)
    lo, hi = collective_flip_check(eng.mesh, eng.registry.version)
    assert lo == hi == eng.registry.version


# ---------------------------------------------------------------------------
# Degraded serving on a mesh
# ---------------------------------------------------------------------------

def test_degraded_slot_serving_on_mesh(setup):
    cfg = setup[0]
    eng = make_engine(setup, mesh_shape=(4, 1), kv_layout="paged",
                      degrade_after_s=0.0)
    eng.submit(99, np.arange(5), max_new_tokens=4)   # never-ingested client
    eng.submit(0, np.arange(5), max_new_tokens=4)
    eng.run()
    rep = eng.report()
    assert rep["degraded_served"] == 1 and rep["requests"] == 2
    degraded = [f for f in eng.finished.values() if f["degraded"]]
    assert len(degraded) == 1 and len(degraded[0]["tokens"]) == 4


# ---------------------------------------------------------------------------
# Spec compliance: placements survive real jitted steps
# ---------------------------------------------------------------------------

def _assert_sharding(leaf, mesh, spec):
    want = NamedSharding(mesh, spec)
    assert leaf.sharding.is_equivalent_to(want, leaf.ndim), (
        f"{leaf.shape}: {leaf.sharding.spec} != {spec}")


@pytest.mark.parametrize("mesh_shape", [(2, 2), (1, 4)])
def test_spec_compliance_after_steps(setup, mesh_shape):
    cfg = setup[0]
    eng = make_engine(setup, mesh_shape=mesh_shape, kv_layout="paged")
    run_tokens(eng, cfg, n=4)
    mesh, dsize = eng.mesh, data_size(eng.mesh)
    msize = mesh.shape["model"]

    # KV pool (decode/prefill OUTPUT: the cache came out of the jitted
    # steps): page axis over "data", KV heads over "model" — leaves are
    # (n, n_pages, page_size, Hkv, hd)
    for leaf in jax.tree_util.tree_leaves(eng.cache):
        if leaf.ndim != 5:
            continue
        page_ax = "data" if leaf.shape[1] % dsize == 0 else None
        head_ax = "model" if leaf.shape[3] % msize == 0 else None
        _assert_sharding(leaf, mesh,
                         P(None, page_ax, None, head_ax, None))

    # base params: tensor-parallel — at least one leaf actually carries
    # the "model" axis (the sanitize fallback must not have replicated
    # everything)
    def has_model(leaf):
        spec = getattr(leaf.sharding, "spec", None) or ()
        return any("model" in (ax if isinstance(ax, tuple) else (ax,))
                   for ax in spec if ax is not None)
    assert any(has_model(l) for l in jax.tree_util.tree_leaves(eng.params))

    # registry tables: NOTHING shards over "data" (any row gathers any
    # slot), and col-parallel B tables split their output dim over
    # "model" when it divides
    saw_b_model = False
    for path, leaf in jax.tree_util.tree_leaves_with_path(
            eng.registry.tables):
        spec = tuple(getattr(leaf.sharding, "spec", None) or ())
        flat = [a for ax in spec if ax is not None
                for a in (ax if isinstance(ax, tuple) else (ax,))]
        assert "data" not in flat, (path, spec)
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name == "B" and spec and spec[-1] == "model":
            saw_b_model = True
    if msize > 1:
        assert saw_b_model, "no col-parallel B table sharded over 'model'"


def test_dense_cache_batch_axis_sharded(setup):
    cfg = setup[0]
    eng = make_engine(setup, mesh_shape=(4, 1), kv_layout="dense")
    run_tokens(eng, cfg, n=4)
    # dense cache leaves are (n, B, S, Hkv, hd): batch axis over "data"
    for leaf in jax.tree_util.tree_leaves(eng.cache):
        if leaf.ndim == 5 and leaf.shape[1] % 4 == 0:
            assert "data" in tuple(leaf.sharding.spec), leaf.sharding.spec


# ---------------------------------------------------------------------------
# Pool shard alignment
# ---------------------------------------------------------------------------

def test_pool_rows_prefer_local_page_shards(setup):
    """With rows and pages both split 4 ways, a full batch allocates
    every row's pages from its own shard block — zero cross-shard
    steals on the aligned workload."""
    cfg = setup[0]
    eng = make_engine(setup, mesh_shape=(4, 1), kv_layout="paged")
    assert eng.pool.n_shards == 4
    run_tokens(eng, cfg, n=4, new_tokens=4)
    assert eng.pool.cross_shard_allocs == 0
    assert eng.report()["cross_shard_allocs"] == 0
