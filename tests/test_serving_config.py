"""ServingConfig (PR 8): one frozen object for every engine knob.

Validation fires at construction (before any device allocation), the
launcher maps argparse flags through ``from_args``, and the engine keeps
a one-release back-compat shim that folds loose kwargs into a config
under a DeprecationWarning — with token parity against the config path.
"""
import argparse

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import init_model
from repro.serving import AdapterRegistry, ServingConfig, ServingEngine
from repro.serving.config import FIELD_NAMES
from repro.serving.demo import synthetic_clients

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(kv_layout="ragged"),
    dict(attn_backend="cuda"),
    dict(lora_backend="cutlass"),
    dict(decode_backend="speculative"),
    dict(max_batch=0),
    dict(max_seq=0),
    dict(decode_ticks=0),
    dict(page_size=12),                      # not a power of two
    dict(page_size=0),
    dict(n_pages=1),                         # write-off page needs a peer
    dict(n_pages=8, kv_layout="dense"),      # dense has no pool
    dict(kv_layout="dense", attn_backend="pallas"),
    dict(max_queue=-1),
    dict(request_deadline_s=-0.5),
    dict(degrade_after_s=-1.0),
    dict(host_ring_slots=-1),
    dict(prefetch_lookahead=-1),
    dict(prefetch_lookahead=2),              # lookahead without a tier
    dict(mesh_shape=(2, 1)),                 # mesh without shard_serving
    dict(shard_serving=True, attn_backend="pallas"),
    dict(shard_serving=True, mesh_shape=(2,)),
    dict(shard_serving=True, mesh_shape=(2, 0)),
    dict(shard_serving=True, mesh_shape=(3, 1)),   # 3 ∤ max_batch=8
    dict(shard_serving=True, mesh_shape=(2, 2), max_batch=5),
])
def test_rejects_invalid_combinations(bad):
    with pytest.raises(ValueError):
        ServingConfig(**bad)


def test_zero_means_immediately_is_legal():
    cfg = ServingConfig(request_deadline_s=0.0, degrade_after_s=0.0,
                        max_queue=0)
    assert cfg.request_deadline_s == 0.0


def test_tiered_property_and_replace():
    cfg = ServingConfig()
    assert not cfg.tiered
    assert cfg.replace(host_ring_slots=8).tiered
    assert cfg.replace(cold_dir="/tmp/x").tiered
    # replace() revalidates the whole config
    with pytest.raises(ValueError):
        cfg.replace(prefetch_lookahead=4)
    cfg.replace(host_ring_slots=8, prefetch_lookahead=4)


def test_frozen_and_field_names():
    cfg = ServingConfig()
    with pytest.raises(Exception):
        cfg.max_batch = 4
    assert "max_batch" in FIELD_NAMES and "prefetch_lookahead" in FIELD_NAMES
    # engine_kwargs round-trips through the constructor
    assert ServingConfig(**cfg.engine_kwargs()) == cfg


# ---------------------------------------------------------------------------
# from_args: the launcher's flag → field mapping
# ---------------------------------------------------------------------------

def test_from_args_maps_flags_and_overrides():
    ns = argparse.Namespace(kv_layout="paged", page_size=8,
                            attn_backend="xla", lora_backend="bgmv",
                            decode_backend="fused", decode_ticks=4,
                            max_queue=16, request_deadline=1.5,
                            degrade_after=2.0, host_ring_slots=32,
                            cold_dir="/tmp/cold", prefetch_lookahead=4)
    cfg = ServingConfig.from_args(ns, max_batch=4, max_seq=48)
    assert cfg.max_batch == 4 and cfg.max_seq == 48
    assert cfg.request_deadline_s == 1.5     # flag name != field name
    assert cfg.degrade_after_s == 2.0
    assert cfg.host_ring_slots == 32 and cfg.prefetch_lookahead == 4
    assert cfg.decode_backend == "fused" and cfg.decode_ticks == 4


def test_from_args_tolerates_missing_flags():
    cfg = ServingConfig.from_args(argparse.Namespace(page_size=32))
    assert cfg.page_size == 32
    assert cfg.max_batch == ServingConfig().max_batch


def test_from_args_mesh_knobs():
    """--shard-serving / --mesh-shape: the DATAxMODEL string parses to a
    tuple; a sharded default-mesh config carries mesh_shape=None."""
    ns = argparse.Namespace(shard_serving=True, mesh_shape="4x2",
                            max_batch=8)
    cfg = ServingConfig.from_args(ns)
    assert cfg.shard_serving and cfg.mesh_shape == (4, 2)
    cfg = ServingConfig.from_args(
        argparse.Namespace(shard_serving=True, mesh_shape=None))
    assert cfg.shard_serving and cfg.mesh_shape is None
    for bad in ("4", "4x2x1", "axb", ""):
        with pytest.raises(ValueError, match="DATAxMODEL"):
            ServingConfig.from_args(
                argparse.Namespace(shard_serving=True, mesh_shape=bad))


def test_engine_rejects_indivisible_slot_count():
    """The engine validates n_slots % data BEFORE building the mesh, so
    the rejection fires even on a single-device host."""
    cfg, acfg, params, base, trees = engine_setup()
    reg = make_registry(base, trees)                 # n_slots=2
    with pytest.raises(ValueError, match="n_slots"):
        ServingEngine(cfg, params, acfg, reg,
                      ServingConfig(max_batch=4, max_seq=16,
                                    shard_serving=True, mesh_shape=(4, 1)))


# ---------------------------------------------------------------------------
# Engine shim: loose kwargs warn, then behave identically
# ---------------------------------------------------------------------------

def engine_setup():
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)
    acfg = AdapterConfig(mode="fedsa", rank=4)
    params = init_model(KEY, cfg, jnp.float32)
    base = init_adapters(KEY, cfg, acfg)
    trees = [t["adapters"] for t in
             synthetic_clients({"adapters": base}, 3, seed=50, scale=0.05)]
    return cfg, acfg, params, base, trees


def make_registry(base, trees):
    reg = AdapterRegistry({"adapters": base}, n_slots=2)
    for i, t in enumerate(trees):
        reg.ingest(i, {"adapters": t})
    return reg


def run_tokens(eng, cfg, n=4):
    rng = np.random.default_rng(5)
    for i, p in enumerate(rng.integers(0, cfg.vocab_size, (n, 5))):
        eng.submit(i % 3, p, max_new_tokens=4)
    eng.run()
    return {r: eng.finished[r]["tokens"].tolist() for r in eng.finished}


def test_legacy_kwargs_warn_and_match_config(recwarn):
    cfg, acfg, params, base, trees = engine_setup()
    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy = ServingEngine(cfg, params, acfg,
                               make_registry(base, trees),
                               max_batch=2, max_seq=16,
                               kv_layout="paged", page_size=8)
    modern = ServingEngine(cfg, params, acfg, make_registry(base, trees),
                           ServingConfig(max_batch=2, max_seq=16,
                                         kv_layout="paged", page_size=8))
    assert run_tokens(legacy, cfg) == run_tokens(modern, cfg)
    assert legacy.max_batch == modern.max_batch == 2
    assert legacy.kv_layout == modern.kv_layout == "paged"


def test_legacy_kwargs_fold_on_top_of_config():
    cfg, acfg, params, base, trees = engine_setup()
    with pytest.warns(DeprecationWarning):
        eng = ServingEngine(cfg, params, acfg, make_registry(base, trees),
                            ServingConfig(max_batch=2, max_seq=16),
                            page_size=8)
    assert eng.page_size == 8 and eng.max_batch == 2


def test_unknown_kwarg_is_a_type_error():
    cfg, acfg, params, base, trees = engine_setup()
    with pytest.raises(TypeError, match="max_batches"):
        ServingEngine(cfg, params, acfg, make_registry(base, trees),
                      max_batches=2)


def test_invalid_combo_fails_before_device_work():
    cfg, acfg, params, base, trees = engine_setup()
    with pytest.raises(ValueError, match="pallas"):
        ServingConfig(kv_layout="dense", attn_backend="pallas")
    # and via the shim, same failure (after the warning)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError):
            ServingEngine(cfg, params, acfg, make_registry(base, trees),
                          kv_layout="dense", attn_backend="pallas")
