"""Hierarchical adapter store (PR 8): HBM slots → host ring → cold npz.

Covers the tier transitions the registry rides on — bit-exact
demote→promote round trips (versioned double-buffer and paired-A/B
tables rewrite slots from store bytes, so any drift would corrupt
serving), write-once demotion, prefetch overlap, and the all-pinned
cold-miss path.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.serving import AdapterRegistry, AdapterStore, Prefetcher
from repro.serving.demo import synthetic_clients

KEY = jax.random.PRNGKey(0)


def leaves_of(n=3, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((4, 8)).astype(dtype) for _ in range(n)]


def bits(leaves):
    return [x.tobytes() for x in leaves]


# ---------------------------------------------------------------------------
# AdapterStore unit semantics
# ---------------------------------------------------------------------------

def test_store_demote_promote_bit_exact(tmp_path):
    store = AdapterStore(host_ring_slots=2, cold_dir=str(tmp_path))
    want = {c: leaves_of(seed=c) for c in range(5)}
    for c, lv in want.items():
        store.put(c, lv)
    # ring holds the 2 MRU clients; 0..2 were demoted to npz
    assert store.host_count == 2 and store.cold_count == 3
    for c, lv in want.items():
        got, tier = store.fetch(c)
        assert bits(got) == bits(lv), f"client {c} drifted via {tier}"
    # a second full sweep: every entry has round-tripped at least once
    for c, lv in want.items():
        got, _ = store.fetch(c)
        assert bits(got) == bits(lv)
    assert store.promotions > 0 and store.demotions > 0


def test_store_write_once_demotion(tmp_path):
    """An entry promoted from cold is born clean: demoting it again must
    NOT rewrite the npz file (steady-state ring churn is fsync-free)."""
    store = AdapterStore(host_ring_slots=1, cold_dir=str(tmp_path))
    store.put(0, leaves_of(seed=0))
    store.put(1, leaves_of(seed=1))          # demotes 0 (dirty: written)
    path0 = tmp_path / "adapter_0.npz"
    stamp = path0.stat().st_mtime_ns
    store.fetch(0)                           # promotes 0, demotes 1
    store.fetch(1)                           # promotes 1, demotes 0 again
    assert path0.stat().st_mtime_ns == stamp, \
        "clean demotion rewrote the cold file"


def test_store_ring_zero_is_all_cold(tmp_path):
    store = AdapterStore(host_ring_slots=0, cold_dir=str(tmp_path))
    lv = leaves_of(seed=3)
    store.put(7, lv)
    assert store.host_count == 0 and store.tier_of(7) == "cold"
    got, tier = store.fetch(7)
    assert tier == "cold" and bits(got) == bits(lv)
    assert store.promotions == 0             # nothing to promote into
    assert store.tier_of(7) == "cold"


def test_store_formats_and_unknown_client():
    store = AdapterStore(formats=[np.dtype(np.float32)])
    store.put(0, [np.arange(6, dtype=np.float64).reshape(2, 3)])
    got, tier = store.fetch(0)
    assert tier == "host" and got[0].dtype == np.float32
    with pytest.raises(KeyError):
        store.fetch(99)


def test_store_migrate_preserves_bytes_and_order(tmp_path):
    src = AdapterStore(host_ring_slots=2, cold_dir=str(tmp_path / "a"))
    want = {c: leaves_of(seed=10 + c) for c in range(4)}
    for c, lv in want.items():
        src.put(c, lv)
    dst = AdapterStore(host_ring_slots=2, cold_dir=str(tmp_path / "b"))
    dst.migrate_from(src)
    assert len(dst) == len(want)
    assert dst.host_count == 2               # same ring occupancy
    for c, lv in want.items():
        assert bits(dst.fetch(c)[0]) == bits(lv)


def test_prefetcher_promotes_and_dedups(tmp_path):
    store = AdapterStore(host_ring_slots=4, cold_dir=str(tmp_path))
    for c in range(8):
        store.put(c, leaves_of(seed=c))
    pf = Prefetcher(store)
    cold = [c for c in range(8) if store.tier_of(c) == "cold"]
    assert pf.request(cold[0])
    assert pf.drain(), ("prefetcher did not go idle within the drain "
                        "timeout (worker thread starved or wedged)")
    assert store.tier_of(cold[0]) == "host"
    assert not pf.request(cold[0])           # already host-resident
    assert pf.stop(), "prefetcher thread failed to join within timeout"


# ---------------------------------------------------------------------------
# Registry-level tiering
# ---------------------------------------------------------------------------

def fedsa_setup(n_clients=6):
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)
    acfg = AdapterConfig(mode="fedsa", rank=4)
    base = init_adapters(KEY, cfg, acfg)
    template = {"adapters": base}
    trees = synthetic_clients(template, n_clients, seed=50, scale=0.05)
    return template, trees


def test_registry_round_trip_bit_exact_versioned(tmp_path):
    """Versioned registry over a tiny ring: every slot write after a
    demote→promote round trip must reproduce the ingested bytes."""
    template, trees = fedsa_setup()
    reg = AdapterRegistry(template, n_slots=2, versioned=True,
                          host_ring_slots=2, cold_dir=str(tmp_path))
    want = {}
    for i, t in enumerate(trees):
        reg.ingest(i, t)
        want[i] = [x.tobytes() for x in reg._store._format(
            reg._local_leaves(t))]
    assert reg._store.cold_count > 0         # the ring really spilled
    for i in range(len(trees)):              # cycle: evict + promote
        reg.acquire(i)
        reg.release(i)
    for i in range(len(trees)):
        got, _ = reg._store.fetch(i)
        assert [x.tobytes() for x in got] == want[i], f"client {i}"
    assert reg._store.demotions > 0 and reg._store.promotions > 0


def test_registry_round_trip_bit_exact_fedit(tmp_path):
    """Paired A/B tables (fedit): BOTH matrices ride the tiers and must
    come back bit-exact — a mixed round-t A with round-t B would be a
    silent corruption."""
    template, _ = fedsa_setup()
    trees = synthetic_clients(template, 6, mode="fedit", seed=9,
                              scale=0.05)
    reg = AdapterRegistry(template, n_slots=2, mode="fedit",
                          host_ring_slots=2, cold_dir=str(tmp_path))
    assert reg.has_local_A
    want = {}
    for i, t in enumerate(trees):
        reg.ingest(i, t)
        want[i] = [x.tobytes() for x in reg._store._format(
            reg._local_leaves(t))]
    for i in list(range(6)) + [0, 3, 5, 1]:
        reg.acquire(i)
        reg.release(i)
    for i in range(6):
        got, _ = reg._store.fetch(i)
        assert [x.tobytes() for x in got] == want[i]


def test_eviction_demotes_instead_of_discarding(tmp_path):
    template, trees = fedsa_setup()
    reg = AdapterRegistry(template, n_slots=2, host_ring_slots=3,
                          cold_dir=str(tmp_path))
    for i, t in enumerate(trees):
        reg.ingest(i, t)
    reg.acquire(0), reg.release(0)
    reg.acquire(1), reg.release(1)
    reg.acquire(2), reg.release(2)           # evicts 0 → host ring touch
    assert 0 not in reg._lru
    assert reg._store.tier_of(0) in ("host", "cold")
    reg.acquire(0)                           # re-admission, no KeyError
    reg.release(0)


def test_prefetch_converts_cold_miss_to_host_hit(tmp_path):
    template, trees = fedsa_setup()
    reg = AdapterRegistry(template, n_slots=2, host_ring_slots=2,
                          cold_dir=str(tmp_path))
    for i, t in enumerate(trees):
        reg.ingest(i, t)
    cold_cid = next(i for i in range(len(trees))
                    if reg._store.tier_of(i) == "cold")
    assert reg.prefetch(cold_cid) is True
    assert reg.prefetch(cold_cid) is False   # deduped while pending/host
    assert reg.drain_prefetch(), ("prefetch did not complete within the "
                                  "drain timeout (worker thread starved "
                                  "or wedged)")
    before = reg.stats["tier_cold_misses"]
    reg.acquire(cold_cid)
    reg.release(cold_cid)
    st = reg.stats
    assert st["tier_cold_misses"] == before  # no stall: served host-ward
    assert st["tier_host_hits"] >= 1
    assert st["prefetches"] == 1
    tiers = [t for t, _ in reg.admission_samples]
    assert tiers[-1] == "host"


def test_prestage_host_warm_lands_hbm_no_stall(tmp_path):
    """PR 8 gap closed: prefetch of a HOST-warm client with a free HBM
    slot pre-stages it straight into the slot table, so the eventual
    admission is a plain registry hit — no tier fetch, no stall."""
    template, trees = fedsa_setup()
    reg = AdapterRegistry(template, n_slots=3, host_ring_slots=4,
                          cold_dir=str(tmp_path))
    for i, t in enumerate(trees):
        reg.ingest(i, t)
    cid = next(i for i in range(len(trees))
               if reg._store.tier_of(i) == "host")
    assert reg._free                         # a free HBM slot exists
    assert reg.prefetch(cid) is True
    assert reg.stats["tier_prestages"] == 1
    assert cid in reg._lru                   # resident before any acquire
    hits, samples = reg.hits, len(reg.admission_samples)
    tier_before = (reg.stats["tier_host_hits"],
                   reg.stats["tier_cold_misses"])
    reg.acquire(cid)
    reg.release(cid)
    assert reg.hits == hits + 1              # served as a resident hit
    new = reg.admission_samples[samples:]
    assert [t for t, _ in new] == ["hbm"]    # zero-stall HBM admission
    assert (reg.stats["tier_host_hits"],     # no host/cold fetch ran
            reg.stats["tier_cold_misses"]) == tier_before
    assert reg.prefetch(cid) is False        # deduped once resident


def test_cold_miss_under_all_pinned_table(tmp_path):
    """All slots pinned: admission still raises RuntimeError (the
    degraded-slot path stays the engine's call), and the FAILED acquire
    books no tier counters or samples."""
    template, trees = fedsa_setup()
    reg = AdapterRegistry(template, n_slots=1, host_ring_slots=1,
                          cold_dir=str(tmp_path))
    for i, t in enumerate(trees):
        reg.ingest(i, t)
    reg.acquire(0)                           # pins the only slot
    before = (reg.stats["tier_host_hits"], reg.stats["tier_cold_misses"],
              len(reg.admission_samples))
    with pytest.raises(RuntimeError, match="pinned"):
        reg.acquire(1)
    after = (reg.stats["tier_host_hits"], reg.stats["tier_cold_misses"],
             len(reg.admission_samples))
    assert after == before
    reg.release(0)
    reg.acquire(1)                           # retry succeeds post-release
    reg.release(1)


def test_zipf_hot_tenants_stay_warm(tmp_path):
    """Zipf(1.0) traffic: the hottest tenants must never regress to the
    cold tier, and non-resident admissions should be mostly host hits."""
    template, trees = fedsa_setup(n_clients=12)
    reg = AdapterRegistry(template, n_slots=2, host_ring_slots=6,
                          cold_dir=str(tmp_path))
    for i, t in enumerate(trees):
        reg.ingest(i, t)
    rng = np.random.default_rng(4)
    ranks = np.arange(1, 13, dtype=np.float64)
    p = 1.0 / ranks
    p /= p.sum()
    trace = rng.choice(12, size=400, p=p)    # client id == rank-1 (hot=0)
    reg.reset_tier_stats()
    cold_stalls = {c: 0 for c in range(12)}
    seen = {c: 0 for c in range(12)}
    for cid in trace:
        cid = int(cid)
        seen[cid] += 1
        if cid not in reg._lru and reg._store.tier_of(cid) == "cold":
            cold_stalls[cid] += 1            # this acquire pays npz I/O
        reg.acquire(cid)
        reg.release(cid)
    st = reg.stats
    # Zipf sanity: the hot head lives in HBM + ring, so its stall rate
    # must sit far below the cold tail's (LRU alone can't make it zero —
    # the engine's prefetch lookahead closes the rest, tested below)
    hot_rate = sum(cold_stalls[c] for c in (0, 1)) / max(
        1, seen[0] + seen[1])
    tail_seen = sum(seen[c] for c in range(6, 12))
    tail_rate = sum(cold_stalls[c] for c in range(6, 12)) / max(
        1, tail_seen)
    assert hot_rate < 0.15, f"hot tenants stalled cold {hot_rate:.0%}"
    assert hot_rate < tail_rate / 2, (hot_rate, tail_rate)
    # raw LRU (no prefetch) over a half-fleet ring: roughly half the
    # non-resident admissions land host-side; the bench's ≥0.8 gate
    # needs the prefetch lookahead on top
    assert st["host_hit_rate"] is not None and st["host_hit_rate"] >= 0.4
    occ = st["tier_occupancy"]
    assert occ["hbm"] == 2 and occ["host"] == 6
    assert occ["hbm"] + occ["host"] + occ["cold"] >= 12


def test_stats_slot_breakdown(tmp_path):
    template, trees = fedsa_setup()
    reg = AdapterRegistry(template, n_slots=3, host_ring_slots=4,
                          cold_dir=str(tmp_path))
    for i, t in enumerate(trees):
        reg.ingest(i, t)
    reg.acquire(0)                           # pinned
    reg.acquire(1)
    reg.release(1)                           # resident, unpinned
    st = reg.stats
    assert st["pinned_slots"] == 1
    assert st["unpinned_resident"] == 1
    assert st["free_slots"] == 1
    assert st["degraded_slots"] == 1
    assert st["host_ring_slots"] == 4
    assert st["tier_occupancy"]["hbm"] == 2


def test_engine_issues_prefetches_from_lookahead(tmp_path):
    """End to end: a tiered engine walks the scheduler's queue at each
    host-sync boundary and promotes upcoming admits host-ward — the
    report counts prefetches and the trace carries the new events."""
    from repro.models.transformer import init_model
    from repro.obs import TraceLog
    from repro.serving import ServingConfig, ServingEngine

    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)
    acfg = AdapterConfig(mode="fedsa", rank=4)
    params = init_model(KEY, cfg, jnp.float32)
    base = init_adapters(KEY, cfg, acfg)
    trees = synthetic_clients({"adapters": base}, 8, seed=50, scale=0.05)
    reg = AdapterRegistry({"adapters": base}, n_slots=2,
                          host_ring_slots=2, cold_dir=str(tmp_path))
    for i, t in enumerate(trees):
        reg.ingest(i, t)
    trace = TraceLog(validate=True)
    eng = ServingEngine(cfg, params, acfg, reg,
                        ServingConfig(max_batch=2, max_seq=16,
                                      host_ring_slots=2,
                                      cold_dir=str(tmp_path),
                                      prefetch_lookahead=2),
                        trace=trace)
    rng = np.random.default_rng(2)
    for r in range(8):
        eng.submit(r, rng.integers(0, cfg.vocab_size, 4),
                   max_new_tokens=3)
    rep = eng.run()
    assert rep["requests"] == 8
    assert rep["prefetches"] > 0
    kinds = {rec["ev"] for rec in trace}
    assert "adapter_prefetch" in kinds
    assert rep["tier_occupancy"]["hbm"] == 2


def test_configure_tiers_migrates(tmp_path):
    template, trees = fedsa_setup()
    reg = AdapterRegistry(template, n_slots=2)   # unbounded host store
    for i, t in enumerate(trees):
        reg.ingest(i, t)
    want = {i: [x.tobytes() for x in reg._store[i]]
            for i in range(len(trees))}
    reg.configure_tiers(host_ring_slots=2, cold_dir=str(tmp_path))
    assert reg._store.host_count == 2
    for i in range(len(trees)):
        assert [x.tobytes() for x in reg._store.fetch(i)[0]] == want[i]
