"""Property tests for the serving bucketing laws (hypothesis-backed;
skip cleanly on bare environments via the conftest shim).

Three functions carry every padding/retrace bound in the paged engine:
``bucket_len`` (pow2 length buckets), ``ServingEngine._page_bucket``
(the half-pow2 {2^k, 3·2^k} ladder), and ``PagePool.pages_needed``
(ceil-div page counts). Their algebraic properties — minimality,
monotonicity, ladder membership, alignment — are what the retrace and
reservation arguments in engine.py actually rest on.
"""
import pytest

from conftest import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401

from repro.serving import PagePool, ServingEngine, bucket_len


@given(st.integers(0, 1 << 16), st.sampled_from([1, 2, 4, 8, 16, 64]))
def test_bucket_len_is_minimal_pow2_cover(n, lo):
    b = bucket_len(n, lo)
    assert b >= max(n, lo)
    assert b & (b - 1) == 0                  # a power of two
    assert b == lo or b // 2 < max(n, lo)    # minimal: half would miss
    assert b % lo == 0                       # whole multiples of the floor


@given(st.integers(0, 1 << 16), st.integers(0, 1 << 16))
def test_bucket_len_is_monotone(n, m):
    if n <= m:
        assert bucket_len(n) <= bucket_len(m)
    else:
        assert bucket_len(n) >= bucket_len(m)


@given(st.integers(1, 1 << 16))
def test_page_bucket_on_ladder_minimal_and_tight(n):
    b = ServingEngine._page_bucket(n)
    # membership: b is 2^k or 3·2^k
    assert b & (b - 1) == 0 or (b % 3 == 0 and
                                (b // 3) & (b // 3 - 1) == 0)
    assert n <= b                            # covers the request
    assert b <= max(2, -(-3 * n // 2))       # within 1.5x (except n=1→1,2)
    # minimality: no smaller ladder rung covers n
    smaller = {1 << k for k in range(17)} | {3 << k for k in range(16)}
    assert not any(n <= r < b for r in smaller)


@given(st.integers(0, 1 << 20), st.sampled_from([1, 2, 4, 8, 16, 128]))
def test_pages_needed_is_ceil_div(n_tokens, page_size):
    pool = PagePool(n_pages=4, page_size=page_size)
    got = pool.pages_needed(n_tokens)
    assert got * page_size >= n_tokens       # covers every token
    assert (got - 1) * page_size < n_tokens or got == 0   # no slack page
    assert got == (n_tokens + page_size - 1) // page_size


# deterministic edge cases — these run even without hypothesis
@pytest.mark.parametrize("page_size", [1, 4, 16])
def test_pages_needed_edges(page_size):
    pool = PagePool(n_pages=4, page_size=page_size)
    assert pool.pages_needed(0) == 0                 # 0-token prompt
    assert pool.pages_needed(1) == 1
    for k in (1, 2, 7):                              # exact multiples
        assert pool.pages_needed(k * page_size) == k
        assert pool.pages_needed(k * page_size + 1) == k + 1
    max_seq = 64
    assert pool.pages_needed(max_seq) == -(-max_seq // page_size)


def test_bucket_len_edges():
    assert bucket_len(0) == 1 and bucket_len(1) == 1
    assert bucket_len(0, 16) == 16
    assert [bucket_len(n, 16) for n in (15, 16, 17, 32, 33)] == \
        [16, 16, 32, 32, 64]
