"""Paged KV cache + chunked batched prefill: engine-level parity with the
dense layout, page-pool fragmentation/reuse, bounded jit retraces, and
the bgmv / pallas backend wiring."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import init_model
from repro.serving import (AdapterRegistry, PagePool, Scheduler,
                           ServingConfig, ServingEngine, bucket_len,
                           prefill_batches)
from repro.serving.demo import synthetic_clients

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)
    acfg = AdapterConfig(mode="fedsa", rank=4)
    params = init_model(KEY, cfg, jnp.float32)
    base = init_adapters(KEY, cfg, acfg)
    trees = [t["adapters"] for t in
             synthetic_clients({"adapters": base}, 5, seed=50, scale=0.05)]
    return cfg, acfg, params, base, trees


def make_registry(base, trees, n_slots):
    reg = AdapterRegistry({"adapters": base}, n_slots=n_slots)
    for i, t in enumerate(trees):
        reg.ingest(i, {"adapters": t})
    return reg


def make_engine(setup, **kw):
    cfg, acfg, params, base, trees = setup
    reg = make_registry(base, trees, kw.pop("n_slots", 2))
    return ServingEngine(cfg, params, acfg, reg, ServingConfig(**kw))


def serve(eng, prompts, *, n_clients=3, new_tokens=5):
    for i, p in enumerate(prompts):
        eng.submit(i % n_clients, p, max_new_tokens=new_tokens)
    rep = eng.run()
    return rep, {r: eng.finished[r]["tokens"].tolist() for r in eng.finished}


HETERO = [6, 13, 4, 9, 17, 6, 11, 3]


def hetero_prompts(cfg, lens=HETERO, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(n)) for n in lens]


# ---------------------------------------------------------------------------
# paged vs dense exact parity (the tentpole invariant)
# ---------------------------------------------------------------------------

def test_paged_vs_dense_token_parity(setup):
    """Same mixed-client prompts through both layouts (slot eviction
    churn included) → token-identical output per request."""
    cfg = setup[0]
    prompts = hetero_prompts(cfg)
    _, want = serve(make_engine(setup, max_batch=2, max_seq=32,
                                kv_layout="dense"), prompts)
    rep, got = serve(make_engine(setup, max_batch=2, max_seq=32,
                                 kv_layout="paged", page_size=8), prompts)
    assert got == want
    assert rep["requests"] == len(prompts)
    assert rep["kv_layout"] == "paged"
    assert 0.0 < rep["page_utilization"] <= 1.0
    assert 0.0 < rep["pool_occupancy"] <= 1.0


def test_paged_pallas_attn_backend_parity(setup):
    """attn_backend="pallas" routes decode through the Pallas paged
    kernel (interpret mode on CPU) — tokens must not change."""
    cfg = setup[0]
    prompts = hetero_prompts(cfg, lens=[6, 13, 4, 9])
    _, want = serve(make_engine(setup, max_batch=2, max_seq=32,
                                kv_layout="paged", page_size=8), prompts)
    _, got = serve(make_engine(setup, max_batch=2, max_seq=32,
                               kv_layout="paged", page_size=8,
                               attn_backend="pallas"), prompts)
    assert got == want


def test_engine_bgmv_lora_backend_parity(setup):
    """lora_backend="bgmv" fuses the grouped decode matmul into the
    Pallas bgmv kernel — engine-level token parity with the jnp branch,
    on both layouts."""
    cfg = setup[0]
    prompts = hetero_prompts(cfg, lens=[6, 13, 4, 9])
    _, want = serve(make_engine(setup, max_batch=2, max_seq=32,
                                kv_layout="paged", page_size=8), prompts)
    for layout in ("paged", "dense"):
        _, got = serve(make_engine(setup, max_batch=2, max_seq=32,
                                   kv_layout=layout, page_size=8,
                                   lora_backend="bgmv"), prompts)
        assert got == want, layout


# ---------------------------------------------------------------------------
# page pool: fragmentation / reuse / exhaustion
# ---------------------------------------------------------------------------

def test_pagepool_retire_frees_and_reuses_pages(setup):
    _, _, _, base, trees = setup
    reg = make_registry(base, trees, n_slots=2)
    pool = PagePool(n_pages=5, page_size=4)          # capacity 4
    sched = Scheduler(max_batch=2, pool=pool, table_pages=2)
    for i in range(3):                               # 2 pages each
        sched.submit(i % 2, np.zeros(6, np.int32), max_new_tokens=2)
    first = sched.admit(reg)
    assert len(first) == 2 and pool.free_count == 0
    held = {row: set(seq.pages) for row, seq in sched.active.items()}
    assert held[0].isdisjoint(held[1])
    assert 0 not in held[0] | held[1]                # write-off reserved
    assert sched.admit(reg) == []                    # pool exhausted
    sched.active[0].generated.extend([1, 1])
    sched.retire(0, reg)
    assert pool.free_count == 2                      # pages released
    assert not np.any(sched.block_tables[0])         # row remapped to 0
    nxt = sched.admit(reg)
    assert len(nxt) == 1
    assert set(nxt[0].pages) == held[0]              # physical reuse


def test_engine_pool_exhaustion_queues_and_drains(setup):
    """A pool half the worst case: admission throttles instead of
    overflowing, and every request still completes."""
    cfg = setup[0]
    prompts = hetero_prompts(cfg)
    eng = make_engine(setup, max_batch=4, max_seq=32, kv_layout="paged",
                      page_size=8, n_pages=9)        # 2 full seqs max
    rep, got = serve(eng, prompts)
    assert rep["requests"] == len(prompts)
    assert eng.pool.free_count == eng.pool.capacity  # nothing leaked
    _, want = serve(make_engine(setup, max_batch=4, max_seq=32,
                                kv_layout="dense"), prompts)
    assert got == want                               # throttling is exact


def test_submit_rejects_pool_overflow(setup):
    eng = make_engine(setup, max_batch=2, max_seq=32, kv_layout="paged",
                      page_size=8, n_pages=3)        # capacity 2 pages
    with pytest.raises(AssertionError):
        eng.submit(0, np.zeros(20, np.int32), max_new_tokens=5)


# ---------------------------------------------------------------------------
# bucketed prefill: bounded retraces
# ---------------------------------------------------------------------------

def test_bucketed_prefill_retrace_count(setup):
    """14 distinct prompt lengths must land in O(log max_seq · log
    max_batch) compiled prefill variants (dense retraces once per
    length), and decode in O(log table_pages) variants."""
    cfg = setup[0]
    lens = [3, 4, 5, 6, 7, 9, 11, 13, 17, 19, 23, 29, 31, 33]
    prompts = hetero_prompts(cfg, lens=lens)
    eng = make_engine(setup, max_batch=4, max_seq=64, kv_layout="paged",
                      page_size=16, n_slots=3)
    rep, _ = serve(eng, prompts, new_tokens=3)
    # length buckets {16, 32, 64} × group-size buckets {1, 2, 4}
    assert rep["prefill_retraces"] <= 9 < len(set(lens))
    # page-count buckets {1, 2, 4}
    assert rep["decode_retraces"] <= 3
    assert rep["prefill_batches"] < len(lens)        # batching happened


def test_page_bucket_ladder_edges():
    """The half-pow2 {2^k, 3·2^k} ladder: exact powers of two map to
    themselves, everything else lands on the next ladder rung (within
    1.5x of the request), and the ladder is monotone."""
    bucket = ServingEngine._page_bucket
    # exact pow2 rungs
    for k in range(7):
        assert bucket(1 << k) == 1 << k
    # 3·2^k rungs (n=3 is the first half-step; n=2 stays pow2)
    assert [bucket(n) for n in (3, 6, 12, 24)] == [3, 6, 12, 24]
    # boundaries: one past a rung climbs to the NEXT rung, never further
    assert [bucket(n) for n in (5, 7, 9, 13, 17, 25)] == \
        [6, 8, 12, 16, 24, 32]
    # within 1.5x of the request, ladder monotone
    prev = 0
    for n in range(1, 200):
        b = bucket(n)
        assert n <= b <= max(2, -(-3 * n // 2))
        assert b >= prev
        prev = b
    # the engine caps the bucket at the pages max_seq needs (a non-pow2
    # max_seq would otherwise overshoot the dense layout) — the cap is
    # applied at the call sites via min(); the raw ladder may exceed it
    assert bucket(9) == 12


def test_fused_decode_retraces_stay_olog(setup):
    """Under the fused loop the decode trace key is (page bucket, T):
    both families are O(log), so 14 distinct prompt lengths with mixed
    budgets still land in a handful of compiled scan variants."""
    cfg = setup[0]
    lens = [3, 4, 5, 6, 7, 9, 11, 13, 17, 19, 23, 29, 31, 33]
    prompts = hetero_prompts(cfg, lens=lens)
    eng = make_engine(setup, max_batch=4, max_seq=64, kv_layout="paged",
                      page_size=16, n_slots=3, decode_backend="fused",
                      decode_ticks=8)
    rep, _ = serve(eng, prompts, new_tokens=6)
    # page buckets {1, 2, 3, 4} x tick counts {1, 2, 4} — and far fewer
    # pairs actually occur; the bound that matters is
    # O(log max_seq * log decode_ticks), never O(#lengths)
    assert rep["decode_retraces"] <= 12 < len(set(lens))
    assert rep["prefill_retraces"] <= 9


def test_bucket_len_and_prefill_batches():
    assert [bucket_len(n, 16) for n in (1, 16, 17, 33, 64)] == \
        [16, 16, 32, 64, 64]

    class Seq:                                       # minimal stand-in
        def __init__(self, n):
            self.request = type("R", (), {"prompt": np.zeros(n)})()

    groups = prefill_batches([Seq(3), Seq(20), Seq(16), Seq(40)],
                             min_len=16)
    assert [(L, len(g)) for L, g in groups] == [(16, 2), (32, 1), (64, 1)]


# ---------------------------------------------------------------------------
# accounting + layout guards
# ---------------------------------------------------------------------------

def test_report_token_accounting(setup):
    """prefill_tokens counts prompt tokens (not one per request);
    generated/decode tokens and the decode-only rate are separated."""
    cfg = setup[0]
    lens, new_tokens = [6, 13, 4, 9], 5
    prompts = hetero_prompts(cfg, lens=lens)
    for layout in ("paged", "dense"):
        rep, _ = serve(make_engine(setup, max_batch=2, max_seq=32,
                                   kv_layout=layout, page_size=8), prompts,
                       new_tokens=new_tokens)
        assert rep["prefill_tokens"] == sum(lens), layout
        assert rep["generated_tokens"] == len(lens) * new_tokens
        assert rep["decode_tokens"] == len(lens) * (new_tokens - 1)
        assert rep["tokens"] == sum(lens) + rep["decode_tokens"]
        assert rep["decode_tok_per_s"] > 0


def test_paged_layout_rejects_ssm_and_auto_falls_back(setup):
    _, _, _, base, trees = setup
    ssm_cfg = reduced(get_config("falcon-mamba-7b"))
    assert ssm_cfg.family == "ssm"
    reg = make_registry(base, trees, n_slots=2)
    acfg = setup[1]
    with pytest.raises(NotImplementedError):
        ServingEngine(ssm_cfg, None, acfg, reg,
                      ServingConfig(max_batch=2, max_seq=16,
                                    kv_layout="paged"))
    eng = ServingEngine(ssm_cfg, None, acfg, reg,
                        ServingConfig(max_batch=2, max_seq=16))
    assert eng.kv_layout == "dense"                  # auto fallback
