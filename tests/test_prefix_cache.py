"""Copy-on-write prefix caching for the paged KV pool (PR 10).

Covers the refcount layer (double-release / share-of-free guards, shared
pages recycling only at the last holder), the PrefixCache unit semantics
(chain hashing, chunk + tail entries, namespace isolation, LRU eviction
that skips live pages), and the engine-level acceptance matrix: token
parity cache-on vs cache-off across decode and LoRA backends, the
mid-decode CoW fork, a prefix hit surviving a live-refresh flip, and the
poisoned-page invariant (shared page bytes never mutate).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, get_config, reduced
from repro.core.adapters import init_adapters
from repro.models.transformer import init_model
from repro.obs import TraceLog, validate_trace
from repro.serving import (AdapterRegistry, PagePool, PrefixCache,
                           ServingConfig, ServingEngine)
from repro.serving.demo import synthetic_clients

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("deepseek-7b"), n_layers=2, d_model=64)
    acfg = AdapterConfig(mode="fedsa", rank=4)
    params = init_model(KEY, cfg, jnp.float32)
    base = init_adapters(KEY, cfg, acfg)
    trees = [t["adapters"] for t in
             synthetic_clients({"adapters": base}, 5, seed=50, scale=0.05)]
    return cfg, acfg, params, base, trees


def make_engine(setup, *, trace=None, versioned=False, **kw):
    cfg, acfg, params, base, trees = setup
    reg = AdapterRegistry({"adapters": base}, n_slots=kw.pop("n_slots", 2),
                          versioned=versioned)
    for i, t in enumerate(trees):
        reg.ingest(i, {"adapters": t})
    return ServingEngine(cfg, params, acfg, reg, ServingConfig(**kw),
                         trace=trace)


def shared_prefix_prompts(cfg, *, prefix_len=16, n=6, seed=1):
    """n prompts sharing a prefix_len-token prefix with divergent
    suffixes, plus one exact repeat of the first (full-prompt hit)."""
    rng = np.random.default_rng(seed)
    head = rng.integers(0, cfg.vocab_size, prefix_len)
    out = [np.concatenate([head, rng.integers(0, cfg.vocab_size, 5 + i)])
           for i in range(n)]
    return out + [out[0].copy()]


def serve(eng, prompts, *, n_clients=3, new_tokens=5):
    for i, p in enumerate(prompts):
        eng.submit(i % n_clients, p, max_new_tokens=new_tokens)
    rep = eng.run()
    return rep, {r: eng.finished[r]["tokens"].tolist() for r in eng.finished}


COMMON = dict(max_batch=2, max_seq=32, kv_layout="paged", page_size=8,
              n_pages=33)


@pytest.fixture(scope="module")
def baseline(setup):
    """Cache-off tokens for the standard shared-prefix workload."""
    prompts = shared_prefix_prompts(setup[0])
    _, want = serve(make_engine(setup, **COMMON), prompts)
    return prompts, want


# ---------------------------------------------------------------------------
# PagePool refcounts: guards + recycle-at-zero
# ---------------------------------------------------------------------------

def test_pool_double_release_raises():
    pool = PagePool(n_pages=5, page_size=4)
    pages = pool.alloc(2)
    pool.release(pages)
    with pytest.raises(ValueError, match="double release"):
        pool.release(pages[:1])
    # the free list holds each page exactly once
    assert pool.free_count == pool.capacity
    assert len(set(sum(pool._frees, []))) == pool.free_count


def test_pool_share_of_free_page_raises():
    pool = PagePool(n_pages=5, page_size=4)
    with pytest.raises(ValueError, match="share of free page"):
        pool.share([3])
    page = pool.alloc(1)[0]
    pool.release([page])
    with pytest.raises(ValueError, match="share of free page"):
        pool.share([page])


def test_pool_shared_page_recycles_at_last_holder():
    pool = PagePool(n_pages=5, page_size=4)
    page = pool.alloc(1)[0]
    pool.share([page])
    assert pool.refcount(page) == 2
    pool.release([page])                     # first holder drops
    assert pool.refcount(page) == 1
    assert pool.free_count == pool.capacity - 1   # still held
    pool.release([page])                     # last holder → recycled
    assert pool.refcount(page) == 0
    assert pool.free_count == pool.capacity
    assert pool.alloc(4) is not None         # whole pool allocatable again


# ---------------------------------------------------------------------------
# PrefixCache unit semantics
# ---------------------------------------------------------------------------

def test_prefix_cache_chunks_tail_and_namespaces():
    pool = PagePool(n_pages=9, page_size=4)
    cache = PrefixCache(pool, chunk_pages=1)
    prompt = np.arange(10, dtype=np.int32)   # 2 full pages + 2-token tail
    pages = pool.alloc(3)
    cache.insert(("a", 0), prompt, pages)
    assert len(cache) == 3                   # 2 chunks + 1 tail
    assert all(pool.refcount(p) == 2 for p in pages)
    # full-prompt hit (chunks + tail)
    matched, got = cache.lookup(("a", 0), prompt)
    assert matched == 10 and got == pages
    # divergent continuation: chunk-aligned partial hit
    other = np.concatenate([prompt[:8], [99, 98, 97]]).astype(np.int32)
    matched, got = cache.lookup(("a", 0), other)
    assert matched == 8 and got == pages[:2]
    # first-token divergence and foreign namespace: clean misses
    assert cache.lookup(("a", 0), np.array([7, 1, 2], np.int32))[0] == 0
    assert cache.lookup(("b", 0), prompt)[0] == 0
    # re-insert of an identical prompt registers nothing new
    inserts = cache.inserts
    cache.insert(("a", 0), prompt, pages)
    assert cache.inserts == inserts and len(cache) == 3


def test_prefix_evict_skips_live_pages():
    pool = PagePool(n_pages=9, page_size=4)
    cache = PrefixCache(pool, chunk_pages=1)
    live = pool.alloc(2)
    cache.insert(("live", 0), np.arange(8, dtype=np.int32), live)
    pool.share(live)                         # a row still reads these
    cold = pool.alloc(2)
    cache.insert(("cold", 0), np.arange(100, 108, dtype=np.int32), cold)
    pool.release(cold)                       # donor retired: cache-only
    # live rows survive even under a demand the pool can't meet
    freed = cache.evict_for(pool, needed=pool.capacity)
    assert freed == 2                        # only the cold chain
    assert cache.lookup(("live", 0), np.arange(8, dtype=np.int32))[0] == 8
    assert cache.lookup(("cold", 0),
                        np.arange(100, 108, dtype=np.int32))[0] == 0
    assert cache.evictions == 2


# ---------------------------------------------------------------------------
# engine acceptance: parity matrix + counters
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("decode_backend", ["per-tick", "fused"])
@pytest.mark.parametrize("lora_backend", ["bgmv", "sgmv"])
def test_cache_on_off_token_parity(setup, baseline, decode_backend,
                                   lora_backend):
    """Cache-on must be token-identical to cache-off across the decode ×
    LoRA backend matrix — while actually sharing pages (hits > 0)."""
    prompts, want = baseline
    rep, got = serve(make_engine(setup, **COMMON, prefix_cache=True,
                                 decode_backend=decode_backend,
                                 lora_backend=lora_backend), prompts)
    assert got == want
    assert rep["prefix_hits"] >= 3
    assert rep["prefix_hit_tokens"] >= 3 * 16
    assert rep["pages_shared"] >= 3
    assert rep["cow_copies"] >= 1
    assert rep["prefix_hit_rate"] > 0


def test_mid_decode_cow_fork(setup, baseline):
    """A full-prompt hit lands while the donor row is mid-decode: the
    donor must have CoW'd its tail page (insert shared it), and both
    rows — identical prompt, identical adapter — emit identical,
    cache-off-identical tokens."""
    cfg = setup[0]
    p = shared_prefix_prompts(cfg)[0]        # 21 tokens: partial tail
    eng = make_engine(setup, **COMMON, prefix_cache=True)
    eng.submit(0, p, max_new_tokens=6)
    eng.step()                               # donor prefilled + decoding
    assert eng.scheduler.active, "donor should still be mid-decode"
    assert eng.cow_copies >= 1               # tail CoW before first write
    eng.submit(0, p, max_new_tokens=6)       # forks the live donor
    rep = eng.run()
    assert rep["prefix_hits"] == 1
    assert rep["prefix_hit_tokens"] == len(p)
    toks = [eng.finished[r]["tokens"].tolist() for r in sorted(eng.finished)]
    assert toks[0] == toks[1]
    off = make_engine(setup, **COMMON)
    off.submit(0, p, max_new_tokens=6)
    off.submit(0, p, max_new_tokens=6)
    off.run()
    want = [off.finished[r]["tokens"].tolist() for r in sorted(off.finished)]
    assert toks == want


def test_shared_pages_never_mutate(setup):
    """The refcount invariant, checked on device bytes: every page the
    cache holds is bit-identical before and after a wave of admissions
    that hit, extend, and decode past the cached prefix."""
    cfg = setup[0]
    prompts = shared_prefix_prompts(cfg)
    eng = make_engine(setup, **COMMON, prefix_cache=True)
    eng.submit(0, prompts[0], max_new_tokens=5)
    eng.run()                                # donor retired; cache holds it
    pages = sorted({p for e in eng.prefix._entries.values() for p in e})
    assert pages

    def snap():
        jax.block_until_ready(eng.cache)
        return [np.asarray(e[k][:, pages]).tobytes()
                for e in eng.cache for k in ("k", "v")]

    before = snap()
    for i, p in enumerate(prompts):          # hits + forks + decode churn
        eng.submit(i % 3, p, max_new_tokens=5)
    rep = eng.run()
    assert rep["prefix_hits"] >= 1 and rep["cow_copies"] >= 1
    # eviction would recycle (and legitimately rewrite) a page: the
    # roomy pool above must not have needed any
    assert rep["prefix_evictions"] == 0
    assert snap() == before, "a shared page's KV bytes changed"


def test_prefix_hit_across_refresh_flip(setup):
    """Live refresh: a flip that does NOT touch a client's bytes keeps
    its cached prefixes valid (hit), while publishing new bytes for the
    client changes its adapter tag and the stale prefix misses — with
    tokens matching a from-scratch engine holding the new bytes."""
    cfg, acfg, params, base, trees = setup
    p = shared_prefix_prompts(cfg)[0]
    eng = make_engine(setup, **COMMON, versioned=True, prefix_cache=True)
    reg = eng.registry

    def serve_one(engine, cid):
        rid = engine.submit(cid, p, max_new_tokens=4)
        engine.run()
        return engine.finished[rid]["tokens"].tolist()

    t0 = serve_one(eng, 0)                   # miss + insert
    t1 = serve_one(eng, 0)                   # full-prompt hit
    assert eng.scheduler.prefix_hits == 1 and t1 == t0
    new = synthetic_clients({"adapters": base}, 5, seed=99, scale=0.05)
    # flip that leaves client 0 untouched → its tag (and prefixes) hold
    assert reg.publish(reg.version + 1, {1: new[1]})
    t2 = serve_one(eng, 0)
    assert eng.scheduler.prefix_hits == 2 and t2 == t0
    # flip client 0's own bytes → stale prefix must miss
    tag_before = reg.adapter_tag(0)
    assert reg.publish(reg.version + 1, {0: new[0]})
    assert reg.adapter_tag(0) != tag_before
    t3 = serve_one(eng, 0)
    assert eng.scheduler.prefix_hits == 2    # no hit on stale KV
    fresh = make_engine(setup, **COMMON, versioned=True)
    fresh.registry.ingest(0, new[0])
    assert serve_one(fresh, 0) == t3         # new-bytes tokens are right


def test_trace_events_and_eviction_under_pressure(setup):
    """A pool with no headroom: admissions reclaim cached prefixes
    (prefix_evict) instead of stalling, hits/CoW still trace, and the
    timeline validates against EVENT_SCHEMA."""
    cfg = setup[0]
    tr = TraceLog(validate=True)
    prompts = shared_prefix_prompts(cfg)
    eng = make_engine(setup, max_batch=2, max_seq=32, kv_layout="paged",
                      page_size=8, n_pages=13, prefix_cache=True, trace=tr)
    rep, got = serve(eng, prompts)
    assert rep["requests"] == len(prompts)
    _, want = serve(make_engine(setup, max_batch=2, max_seq=32,
                                kv_layout="paged", page_size=8,
                                n_pages=13), prompts)
    assert got == want                       # pressure path stays exact
    evs = {e["ev"] for e in tr.events}
    assert "cow_copy" in evs and "prefix_evict" in evs
    assert rep["prefix_evictions"] > 0
    n, errors = validate_trace(tr.to_jsonl())
    assert n == len(tr.events) and not errors


def test_prefix_config_validation(setup):
    with pytest.raises(ValueError, match="dense"):
        ServingConfig(prefix_cache=True, kv_layout="dense")
    with pytest.raises(ValueError, match="shard_serving"):
        ServingConfig(prefix_cache=True, shard_serving=True)
    with pytest.raises(ValueError, match="prefix_chunk_pages"):
        ServingConfig(prefix_chunk_pages=0)
    # auto-resolved dense (SSM family) rejects at engine construction
    cfg, acfg, params, base, trees = setup
    ssm_cfg = reduced(get_config("falcon-mamba-7b"))
    reg = AdapterRegistry({"adapters": base}, n_slots=2)
    with pytest.raises(ValueError, match="paged KV layout"):
        ServingEngine(ssm_cfg, None, acfg, reg,
                      ServingConfig(max_batch=2, max_seq=16,
                                    prefix_cache=True))


def test_prefix_cache_off_reports_zeros(setup, baseline):
    prompts, _ = baseline
    rep, _ = serve(make_engine(setup, **COMMON), prompts)
    assert rep["prefix_hits"] == 0 and rep["pages_shared"] == 0
    assert rep["cow_copies"] == 0 and rep["prefix_entries"] == 0
    assert rep["prefix_hit_rate"] is None
