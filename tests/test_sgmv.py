"""Generic SGMV grouped matmul (per-row A AND B) vs the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(11)


def _operands(M, K, N, r, n_slots, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (M, K), dtype)
    w = (jax.random.normal(ks[1], (K, N), jnp.float32) * 0.05).astype(dtype)
    a = (jax.random.normal(ks[2], (n_slots, K, r), jnp.float32)
         * 0.05).astype(dtype)
    b = (jax.random.normal(ks[3], (n_slots, r, N), jnp.float32)
         * 0.05).astype(dtype)
    sid = jax.random.randint(ks[4], (M,), 0, n_slots)
    return x, w, a, b, sid


@pytest.mark.parametrize("r", [4, 8, 16])
def test_sgmv_rank_sweep(r):
    x, w, a, b, sid = _operands(128, 256, 128, r, n_slots=4)
    y = ops.sgmv(x, w, a, b, sid, 2.0, bm=64, bn=128, bk=128)
    y0 = ref.sgmv_ref(x, w, a, b, sid, 2.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


def test_sgmv_uneven_slots():
    """Skewed slot assignment: most rows on one hot adapter, a few
    scattered — the realistic serving mix."""
    x, w, a, b, _ = _operands(128, 128, 256, 8, n_slots=6)
    sid = jnp.zeros((128,), jnp.int32).at[5].set(3).at[17].set(5).at[100].set(1)
    y = ops.sgmv(x, w, a, b, sid, 1.5, bm=64, bn=128, bk=128)
    y0 = ref.sgmv_ref(x, w, a, b, sid, 1.5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


def test_sgmv_all_same_slot_matches_lora_matmul():
    """Degenerate single-tenant batch must equal the fused lora_matmul
    on that tenant's (A, B) pair."""
    x, w, a, b, _ = _operands(128, 256, 128, 8, n_slots=4)
    sid = jnp.full((128,), 2, jnp.int32)
    y = ops.sgmv(x, w, a, b, sid, 2.0, bm=64, bn=128, bk=128)
    y_fused = ops.lora_matmul(x, w, a[2], b[2], 2.0, bm=64, bn=128, bk=128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_fused),
                               rtol=1e-4, atol=1e-4)


def test_sgmv_shared_A_matches_bgmv():
    """When every slot holds the SAME A (the FedSA invariant), the
    generic kernel must reproduce the shared-Ā fast path exactly —
    the legality condition for the bgmv fallback inside ``adapted``."""
    x, w, a, b, sid = _operands(128, 256, 128, 8, n_slots=4, seed=2)
    a_shared = jnp.broadcast_to(a[0], a.shape)
    y = ops.sgmv(x, w, a_shared, b, sid, 2.0, bm=64, bn=128, bk=128)
    y_bgmv = ops.bgmv(x, w, a[0], b, sid, 2.0, bm=64, bn=128, bk=128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_bgmv),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("blocks", [(64, 128, 128), (128, 128, 256)])
def test_sgmv_block_shapes(blocks):
    bm, bn, bk = blocks
    x, w, a, b, sid = _operands(128, 256, 128, 8, n_slots=4, seed=3)
    y = ops.sgmv(x, w, a, b, sid, 1.0, bm=bm, bn=bn, bk=bk)
    y0 = ref.sgmv_ref(x, w, a, b, sid, 1.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


def test_sgmv_bf16():
    x, w, a, b, sid = _operands(64, 128, 128, 8, n_slots=4,
                                dtype=jnp.bfloat16)
    y = ops.sgmv(x, w, a, b, sid, 2.0, bm=64, bn=128, bk=128)
    y0 = ref.sgmv_ref(x, w, a, b, sid, 2.0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y0, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_sgmv_small_serving_batch():
    """Decode-shaped call: 8 rows (one token per tenant request), every
    row a different personal-A tenant."""
    x, w, a, b, _ = _operands(8, 128, 128, 8, n_slots=8, seed=5)
    sid = jnp.arange(8, dtype=jnp.int32)
    y = ops.sgmv(x, w, a, b, sid, 2.0, bm=8, bn=128, bk=128)
    y0 = ref.sgmv_ref(x, w, a, b, sid, 2.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)
