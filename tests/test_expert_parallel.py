"""Numerics of the shard_map expert-parallel MoE path (§Perf it. 2f).

The EP path only activates on a multi-device mesh with a "model" axis, so
the comparison against the GSPMD capacity-dispatch path runs in a
subprocess with 8 forced host devices.
"""
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced, AdapterConfig
from repro.models.moe import moe_forward, init_moe

cfg = reduced(get_config("granite-moe-3b-a800m"))
# 4 experts divisible by model axis of 2
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, n_experts=4, top_k=2, capacity_factor=8.0))
cfg_ep = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, expert_parallel=True))
acfg = AdapterConfig()
key = jax.random.PRNGKey(0)
p = init_moe(key, cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                      jnp.float32) * 0.3

mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(4, 2),
                         ("data", "model"))
with mesh:
    y0, aux0 = jax.jit(lambda p, x: moe_forward(cfg, p, None, acfg, x))(p, x)
    y1, aux1 = jax.jit(lambda p, x: moe_forward(cfg_ep, p, None, acfg, x))(p, x)
err = float(jnp.max(jnp.abs(y0 - y1)))
assert err < 1e-4, f"EP vs capacity-dispatch mismatch: {err}"
print("OK", err)
"""


def test_expert_parallel_matches_capacity_dispatch():
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, timeout=300,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
