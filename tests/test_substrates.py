"""data / optim / checkpoint / sketch substrate tests (incl. hypothesis)."""
import os

import jax
import jax.numpy as jnp
import numpy as np

# real hypothesis when installed, skip-marking stubs otherwise
from conftest import given, settings, st  # noqa: F401

from repro.core.sketch import compress_roundtrip, make_sketch, sketch
from repro.data.synthetic import (dirichlet_partition,
                                  make_classification_task, make_lm_task,
                                  stack_client_batch)
from repro.optim import adamw, apply_updates, sgd


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.sampled_from([None, 0.1, 0.5, 1.0, 10.0]),
       st.integers(0, 2 ** 31 - 1))
def test_dirichlet_partition_covers_all_indices(n_clients, alpha, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 4, 400)
    parts = dirichlet_partition(labels, n_clients, alpha, rng)
    allidx = np.sort(np.concatenate(parts))
    assert len(allidx) == 400
    np.testing.assert_array_equal(np.unique(allidx), np.arange(400))
    assert all(len(p) >= 8 for p in parts)  # floor guarantee


def test_dirichlet_skew_increases_with_small_alpha():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 4, 4000)

    def skew(alpha):
        parts = dirichlet_partition(labels, 3, alpha,
                                    np.random.default_rng(1))
        tv = 0.0
        for p in parts:
            hist = np.bincount(labels[p], minlength=4) / len(p)
            tv += np.abs(hist - 0.25).sum()
        return tv

    assert skew(0.1) > skew(100.0)


def test_classification_task_learnable_structure():
    clients, tests = make_classification_task(n_clients=3, n_classes=4,
                                              vocab=128, seq=16,
                                              n_train=300, n_test=60)
    assert len(clients) == 3 and len(tests) == 3
    for c in clients:
        assert c["tokens"].shape[1] == 16
        assert c["tokens"].max() < 128
        assert set(np.unique(c["label"])) <= set(range(4))


def test_lm_task_shapes():
    clients, tests = make_lm_task(n_clients=2, vocab=64, seq=32,
                                  n_train=64, n_test=16)
    assert clients[0]["tokens"].shape == (32, 32)
    assert clients[0]["labels"].shape == (32, 32)
    # labels are the next-token shift of the same chain
    assert clients[0]["tokens"].max() < 64


def test_stack_client_batch_rectangular():
    clients, _ = make_classification_task(n_clients=3, vocab=64, seq=8,
                                          n_train=100, alpha=0.1)
    b = stack_client_batch(clients, 16, np.random.default_rng(0))
    assert b["tokens"].shape == (3, 16, 8)
    assert b["label"].shape == (3, 16)


# ---------------------------------------------------------------------------
# optim
# ---------------------------------------------------------------------------

def test_sgd_quadratic_convergence():
    init, update = sgd(0.1, momentum=0.9)
    p = {"x": jnp.asarray([3.0, -2.0])}
    st_ = init(p)
    for i in range(200):
        g = jax.tree_util.tree_map(lambda x: 2 * x, p)
        upd, st_ = update(g, st_, p, step=i)
        p = apply_updates(p, upd)
    assert float(jnp.abs(p["x"]).max()) < 1e-4


def test_adamw_quadratic_convergence():
    init, update = adamw(0.1)
    p = {"x": jnp.asarray([3.0, -2.0])}
    st_ = init(p)
    for i in range(300):
        g = jax.tree_util.tree_map(lambda x: 2 * x, p)
        upd, st_ = update(g, st_, p)
        p = apply_updates(p, upd)
    assert float(jnp.abs(p["x"]).max()) < 1e-3


def test_mask_freezes_leaves():
    init, update = sgd(0.1)
    p = {"a": jnp.ones(3), "b": jnp.ones(3)}
    mask = {"a": jnp.asarray(0.0), "b": jnp.asarray(1.0)}
    g = {"a": jnp.ones(3), "b": jnp.ones(3)}
    upd, _ = update(g, init(p), p, mask)
    p2 = apply_updates(p, upd)
    np.testing.assert_array_equal(np.asarray(p2["a"]), np.asarray(p["a"]))
    assert float(jnp.abs(p2["b"] - p["b"]).max()) > 0


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_pytree_roundtrip(tmp_path):
    from repro.checkpoint import load_pytree, save_pytree
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)},
            "segs": [jnp.zeros((2, 2)), jnp.full((3,), 7.0)]}
    path = os.path.join(tmp_path, "ck.npz")
    save_pytree(path, tree)
    out = load_pytree(path, tree)
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)), tree, out)


def test_federated_checkpoint_split_layout(tmp_path):
    from repro.checkpoint import load_federated, save_federated
    C = 3
    tree = {"wq": {"A": jnp.arange(C * 8, dtype=jnp.float32).reshape(C, 4, 2),
                   "B": jnp.arange(C * 6, dtype=jnp.float32).reshape(C, 2, 3)}}
    # emulate a post-aggregation state: shared A identical across clients
    tree["wq"]["A"] = jnp.broadcast_to(tree["wq"]["A"][:1],
                                       tree["wq"]["A"].shape)
    d = os.path.join(tmp_path, "fed")
    save_federated(d, tree, "fedsa")
    assert os.path.exists(os.path.join(d, "server.npz"))
    assert os.path.exists(os.path.join(d, "client_2.npz"))
    out = load_federated(d, tree, "fedsa")
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_allclose(np.asarray(x),
                                                np.asarray(y)), tree, out)


# ---------------------------------------------------------------------------
# count sketch (Table 10 mechanism)
# ---------------------------------------------------------------------------

def test_sketch_linearity():
    state = make_sketch(0, 256, rows=5, compression=0.5)
    rng = np.random.default_rng(0)
    g1 = jnp.asarray(rng.normal(size=256).astype(np.float32))
    g2 = jnp.asarray(rng.normal(size=256).astype(np.float32))
    s = sketch(state, g1) + sketch(state, g2)
    s12 = sketch(state, g1 + g2)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s12),
                               rtol=1e-5, atol=1e-5)


def test_sketch_recovers_heavy_hitters():
    state = make_sketch(1, 512, rows=7, compression=0.5)
    g = np.zeros(512, np.float32)
    hh = [3, 100, 200, 400]
    g[hh] = [10.0, -8.0, 12.0, -9.0]
    g += np.random.default_rng(2).normal(scale=0.05, size=512)
    est = compress_roundtrip(state, jnp.asarray(g), topk_frac=0.05)
    est = np.asarray(est)
    top = np.argsort(-np.abs(est))[:4]
    assert set(top) == set(hh)
    np.testing.assert_allclose(est[hh], g[hh], atol=1.5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.3, 0.9))
def test_sketch_size_respects_compression(seed, compression):
    dim = 1000
    state = make_sketch(seed, dim, rows=5, compression=compression)
    assert state["rows"] * state["cols"] <= compression * dim + state["rows"]
