"""Train federated FedSA-LoRA in the background WHILE serving it.

The closed loop the paper's split makes possible: a federation round
only publishes one aggregated Ā plus a rank-r B_i per tenant, so the
serving engine can absorb round t+1 mid-stream — sequences admitted
under round t decode round-t weights to their last token (token parity,
no prompt recompute), later admissions read round t+1 from the other
half of the double-buffered slot tables. No drain, no engine rebuild.

  trainer thread: run_rounds(..., publish=feed.publish)
  serving thread: engine.step() → refresh phase → registry flip

  PYTHONPATH=src python examples/train_and_serve.py \
      [--rounds 4] [--clients 3] [--requests 12] [--slots 2]
"""
import argparse

import jax.numpy as jnp

from repro.configs import AdapterConfig, FedConfig, get_config, reduced
from repro.serving import train_and_serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=64)
    args = ap.parse_args()

    cfg = reduced(get_config("deepseek-7b"), n_layers=args.layers,
                  d_model=args.d_model)
    acfg = AdapterConfig(mode="fedsa", rank=4)
    fed = FedConfig(n_clients=args.clients, local_steps=2)

    report, history = train_and_serve(
        cfg, acfg, fed, rounds=args.rounds, n_slots=args.slots,
        requests=args.requests, max_new_tokens=args.new_tokens,
        log=print)
    print(f"train loss {history['loss'][0]:.4f} → "
          f"{history['loss'][-1]:.4f} over {args.rounds} rounds; "
          f"serving ended at adapter version "
          f"{report['adapter_version']} with hit rate "
          f"{report['adapter_hit_rate']:.2f} and "
          f"{report['decode_tok_per_s']:.1f} decode tok/s")
    assert report["adapter_version"] == args.rounds, \
        "engine should end on the final published round"
    assert jnp.isfinite(history["loss"][-1])


if __name__ == "__main__":
    main()
