"""Quickstart: FedSA-LoRA in ~60 lines.

Three clients fine-tune a reduced RoBERTa-style encoder with LoRA on a
non-IID synthetic classification task; only the A matrices are aggregated.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import AdapterConfig, FedConfig, get_config, reduced
from repro.core import federation
from repro.core.similarity import pairwise_similarity
from repro.data.synthetic import make_classification_task

# 1. model: a reduced variant of the paper's RoBERTa backbone
cfg = reduced(get_config("roberta-large"), n_layers=2, d_model=128)

# 2. the paper's technique: LoRA adapters, share-A aggregation
acfg = AdapterConfig(variant="lora", mode="fedsa", rank=8)

# 3. federated setup: 3 clients, Dir(0.5) label skew + client vocab shift
fed = FedConfig(n_clients=3, local_steps=5, dirichlet_alpha=0.5)
clients, tests = make_classification_task(
    n_clients=3, n_classes=4, vocab=cfg.vocab_size, seq=24,
    n_train=1536, alpha=0.5, seed=0)
test_batch = {k: jnp.asarray(np.stack([t[k][:256] for t in tests]))
              for k in tests[0]}

# 4. build + run 30 rounds
system = federation.build(jax.random.PRNGKey(0), cfg, acfg, fed,
                          task="classification", n_classes=4, lr=5e-2)
print(f"trainable params/client: {system.n_trainable:,}   "
      f"uploaded/round: {system.comm_per_round:,} "
      f"(A matrices + head only — B stays local)")

hist = federation.run_rounds(system, clients, rounds=30, batch_size=16,
                             seed=1, eval_every=5, test_batch=test_batch)
print("round losses:", [f"{l:.3f}" for l in hist["loss"][::5]])
print("personalized test accuracy:", [f"{a:.3f}" for a in hist["acc"]])

# 5. the paper's Fig. 2 in one line: A agrees across clients, B diverged
sims = pairwise_similarity(system.trainables["adapters"])
print(f"cross-client cosine similarity — A: {sims['A']:.4f}  "
      f"B: {sims['B']:.4f}")
