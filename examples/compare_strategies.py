"""Example: compare all four aggregation strategies on one non-IID task.

Reproduces the paper's headline comparison (Table 1 row structure) at
laptop scale, printing accuracy + communication for LoRA under fedavg /
ffa / fedsa / feddpa.

  PYTHONPATH=src python examples/compare_strategies.py [--rounds 40]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import AdapterConfig, FedConfig, get_config, reduced
from repro.core import federation
from repro.data.synthetic import make_classification_task


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--variant", default="lora",
                    choices=["lora", "rslora", "vera"])
    args = ap.parse_args()

    cfg = reduced(get_config("roberta-large"), n_layers=2, d_model=128)
    clients, tests = make_classification_task(
        n_clients=3, n_classes=4, vocab=cfg.vocab_size, seq=24,
        n_train=1536, alpha=0.5, hetero_strength=0.35, seed=7)
    test_batch = {k: jnp.asarray(np.stack([t[k][:256] for t in tests]))
                  for k in tests[0]}
    fed = FedConfig(n_clients=3, local_steps=5)

    print(f"{'mode':10s} {'best acc':>9s} {'trainable':>10s} "
          f"{'comm/round':>11s}")
    for mode in ["fedavg", "ffa", "feddpa", "fedsa"]:
        acfg = AdapterConfig(variant=args.variant, mode=mode, rank=8)
        lr = 2e-3 if args.variant == "vera" else 5e-2
        sys = federation.build(jax.random.PRNGKey(0), cfg, acfg, fed,
                               task="classification", n_classes=4, lr=lr)
        hist = federation.run_rounds(sys, clients, rounds=args.rounds,
                                     batch_size=16, seed=1,
                                     eval_every=max(1, args.rounds // 8),
                                     test_batch=test_batch)
        print(f"{mode:10s} {max(hist['acc']):9.4f} "
              f"{sys.n_trainable:10,} {sys.comm_per_round:11,}")


if __name__ == "__main__":
    main()
