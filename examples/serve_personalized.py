"""Serving example: batched decode with a personalized FedSA-LoRA adapter.

Loads (or trains briefly) a federated adapter set, then serves it one of
two ways:

* default — picks one client's personalized model (base + B_i·Ā),
  prefills a batch of prompts and decodes tokens with the KV cache,
* ``--multi-tenant`` — registers EVERY client's B_i with the
  ``repro.serving`` AdapterRegistry and drives a mixed-client request
  stream through the continuous-batching ServingEngine: one decode batch
  carries rows from different clients simultaneously.

  PYTHONPATH=src python examples/serve_personalized.py [--tokens 16]
  PYTHONPATH=src python examples/serve_personalized.py --multi-tenant
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, FedConfig, get_config, reduced
from repro.core import federation
from repro.data.synthetic import make_lm_task
from repro.models.transformer import decode_step, prefill
from repro.serving import AdapterRegistry, ServingConfig, ServingEngine


def serve_multi_tenant(cfg, acfg, system, fed, args):
    """Mixed-client traffic: every request may come from any client."""
    reg = AdapterRegistry.from_system(system, n_slots=fed.n_clients)
    engine = ServingEngine(cfg, system.params, acfg, reg,
                           ServingConfig(max_batch=args.batch,
                                         max_seq=12 + args.tokens))
    rng = np.random.default_rng(3)
    n_requests = 2 * args.batch
    for r in range(n_requests):
        engine.submit(r % fed.n_clients,
                      rng.integers(0, cfg.vocab_size, 12),
                      max_new_tokens=args.tokens)
    rep = engine.run()
    print(f"multi-tenant: {rep['requests']} requests from {fed.n_clients} "
          f"clients → {rep['tokens']} tokens in {rep['wall_s']:.1f}s "
          f"({rep['tok_per_s']:.1f} tok/s, occupancy "
          f"{rep['batch_occupancy']:.2f}, adapter hit rate "
          f"{rep['adapter_hit_rate']:.2f})")
    for rid in sorted(engine.finished)[: args.batch]:
        out = engine.finished[rid]
        print(f"  req{rid} client{out['client_id']}:",
              out["tokens"][:8].tolist())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--client", type=int, default=0)
    ap.add_argument("--multi-tenant", action="store_true")
    args = ap.parse_args()

    cfg = reduced(get_config("deepseek-7b"), n_layers=4, d_model=256)
    acfg = AdapterConfig(mode="fedsa", rank=8)
    fed = FedConfig(n_clients=3, local_steps=4)
    clients, _ = make_lm_task(n_clients=3, vocab=cfg.vocab_size, seq=48,
                              n_train=192, n_test=24, seed=0)
    system = federation.build(jax.random.PRNGKey(0), cfg, acfg, fed,
                              task="lm", lr=5e-2)
    print("federated warm-up (20 rounds)...")
    federation.run_rounds(system, clients, rounds=20, batch_size=8, seed=1)

    if args.multi_tenant:
        return serve_multi_tenant(cfg, acfg, system, fed, args)

    # client i's personalized model: its local B + the aggregated A
    adapters = jax.tree_util.tree_map(lambda x: x[args.client],
                                      system.trainables["adapters"])
    params = system.params

    B, prompt_len, max_seq = args.batch, 12, 12 + args.tokens
    prompts = jax.random.randint(jax.random.PRNGKey(2), (B, prompt_len),
                                 0, cfg.vocab_size)
    t0 = time.time()
    logits, cache, _ = prefill(cfg, params, adapters, acfg, prompts, max_seq)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    out = [tok]
    step = jax.jit(lambda t, p, c: decode_step(cfg, params, adapters, acfg,
                                               t, p, c))
    for i in range(args.tokens - 1):
        pos = jnp.full((B,), prompt_len + i, jnp.int32)
        logits, cache = step(tok, pos, cache)
        tok = jnp.argmax(logits[:, 0], -1)[:, None]
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"prompts {prompts.shape} → generated {gen.shape} "
          f"in {dt:.1f}s ({B*args.tokens/dt:.1f} tok/s on 1 CPU core)")
    for b in range(B):
        print(f"  client{args.client} sample{b}:",
              prompts[b, -4:].tolist(), "→", gen[b, :8].tolist())


if __name__ == "__main__":
    main()
