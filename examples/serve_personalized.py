"""Serving example: batched decode with a personalized FedSA-LoRA adapter.

Loads (or trains briefly) a federated adapter set, picks one client's
personalized model (base + B_i·Ā), prefills a batch of prompts and decodes
tokens with the KV cache — the same ``prefill``/``decode_step`` entry
points the dry-run lowers for the 256-chip mesh, here on CPU at small
scale.

  PYTHONPATH=src python examples/serve_personalized.py [--tokens 16]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import AdapterConfig, FedConfig, get_config, reduced
from repro.core import federation
from repro.data.synthetic import make_lm_task
from repro.models.transformer import decode_step, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--client", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced(get_config("deepseek-7b"), n_layers=4, d_model=256)
    acfg = AdapterConfig(mode="fedsa", rank=8)
    fed = FedConfig(n_clients=3, local_steps=4)
    clients, _ = make_lm_task(n_clients=3, vocab=cfg.vocab_size, seq=48,
                              n_train=192, n_test=24, seed=0)
    system = federation.build(jax.random.PRNGKey(0), cfg, acfg, fed,
                              task="lm", lr=5e-2)
    print("federated warm-up (20 rounds)...")
    federation.run_rounds(system, clients, rounds=20, batch_size=8, seed=1)

    # client i's personalized model: its local B + the aggregated A
    adapters = jax.tree_util.tree_map(lambda x: x[args.client],
                                      system.trainables["adapters"])
    params = system.params

    B, prompt_len, max_seq = args.batch, 12, 12 + args.tokens
    prompts = jax.random.randint(jax.random.PRNGKey(2), (B, prompt_len),
                                 0, cfg.vocab_size)
    t0 = time.time()
    logits, cache, _ = prefill(cfg, params, adapters, acfg, prompts, max_seq)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    out = [tok]
    step = jax.jit(lambda t, p, c: decode_step(cfg, params, adapters, acfg,
                                               t, p, c))
    for i in range(args.tokens - 1):
        pos = jnp.full((B,), prompt_len + i, jnp.int32)
        logits, cache = step(tok, pos, cache)
        tok = jnp.argmax(logits[:, 0], -1)[:, None]
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"prompts {prompts.shape} → generated {gen.shape} "
          f"in {dt:.1f}s ({B*args.tokens/dt:.1f} tok/s on 1 CPU core)")
    for b in range(B):
        print(f"  client{args.client} sample{b}:",
              prompts[b, -4:].tolist(), "→", gen[b, :8].tolist())


if __name__ == "__main__":
    main()
