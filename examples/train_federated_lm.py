"""End-to-end driver: federated LoRA fine-tuning of a ~100M-param causal LM
for a few hundred rounds of local steps on CPU, with checkpointing.

This is the "train a ~100M model for a few hundred steps" example: a
deepseek-style dense decoder (12 layers, d=512, vocab 8192 ≈ 60M params —
the largest that trains in reasonable CPU time; pass --layers/--d-model to
scale up to 100M+) on the synthetic federated LM task.

  PYTHONPATH=src python examples/train_federated_lm.py \
      [--rounds 100] [--mode fedsa] [--layers 12] [--d-model 512]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_federated
from repro.configs import AdapterConfig, FedConfig, get_config, reduced
from repro.core import federation
from repro.core.adapters import n_params
from repro.data.synthetic import make_lm_task


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--mode", default="fedsa",
                    choices=["fedavg", "ffa", "fedsa", "feddpa"])
    ap.add_argument("--variant", default="lora",
                    choices=["lora", "rslora", "vera"])
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--ckpt", default="experiments/ckpt_fed_lm")
    args = ap.parse_args()

    cfg = reduced(get_config("deepseek-7b"), n_layers=args.layers,
                  d_model=args.d_model)
    cfg = dataclasses.replace(cfg, vocab_size=8192, d_ff=args.d_model * 3)
    acfg = AdapterConfig(variant=args.variant, mode=args.mode, rank=8)
    fed = FedConfig(n_clients=args.clients, local_steps=4)

    clients, tests = make_lm_task(n_clients=args.clients,
                                  vocab=cfg.vocab_size, seq=64,
                                  n_train=256 * args.clients, n_test=96,
                                  hetero_strength=0.4, seed=0)
    test_batch = {k: jnp.asarray(np.stack([t[k][:16] for t in tests]))
                  for k in tests[0]}

    system = federation.build(jax.random.PRNGKey(0), cfg, acfg, fed,
                              task="lm", lr=5e-2)
    base_params = sum(x.size for x in
                      jax.tree_util.tree_leaves(system.params))
    print(f"base model: {base_params/1e6:.1f}M params (frozen) | "
          f"adapters/client: {n_params(system.trainables['adapters'])//args.clients:,} | "
          f"uploaded/round: {system.comm_per_round:,}")

    t0 = time.time()
    for block in range(args.rounds // 10):
        hist = federation.run_rounds(system, clients, rounds=10,
                                     batch_size=8, seed=block)
        test_loss = float(jnp.mean(system.eval_fn(system.trainables,
                                                  test_batch)))
        print(f"round {10*(block+1):4d}  train {hist['loss'][-1]:.4f}  "
              f"test {test_loss:.4f}  ({time.time()-t0:.0f}s)", flush=True)

    save_federated(args.ckpt, system.trainables["adapters"], acfg.mode)
    print(f"checkpoint written to {args.ckpt}/ "
          f"(server.npz = aggregated A; client_*.npz = local B)")


if __name__ == "__main__":
    main()
